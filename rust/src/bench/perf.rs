//! §Perf: micro-benchmarks of the hot paths at each layer.
//!
//! L3 native kernels (mesh recompose/apply, full native forward, circuit
//! evaluation, decomposition) plus the PJRT end-to-end execution when
//! artifacts are present. Results are recorded in EXPERIMENTS.md §Perf.

use super::harness::{bench, BenchStats};
use crate::compiler::{plan_shards, Calibration, PerturbMode, PlanSpec, VirtualProcessor};
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::router::{Admin, AdminReply, JobSink, PendingReply, Router};
use crate::coordinator::server::{Backend, ModelBundle};
use crate::coordinator::service::{
    Job, JobResult, PoolConfig, ProcessorPool, ProcessorService, Workload, WIRE_VERSION,
};
use crate::coordinator::sharded::{ShardConfig, ShardedProcessor};
use crate::coordinator::transport::{RemoteClient, TcpConfig, TcpFrontEnd};
use crate::device::State;
use crate::math::c64::C64;
use crate::math::cmat::CMat;
use crate::math::gemm::{self, Micro};
use crate::math::rng::Rng;
use crate::math::svd::svd;
use crate::mesh::decompose::decompose_unitary;
use crate::mesh::propagate::{DiscreteMesh, MeshBackend};
use crate::nn::dspsa::DspsaConfig;
use crate::nn::rfnn_mnist::MnistRfnn;
use crate::processor::{Fidelity, LinearProcessor};
use crate::util::json::Json;
use std::sync::Arc;

/// Batch sizes for the batched-GEMM vs per-vector comparison (the
/// coordinator's BatchPolicy coalesces up to 256).
pub const GEMM_BATCHES: [usize; 4] = [1, 8, 64, 256];

/// Logical processor sizes for the tiled-vs-dense virtualization sweep.
pub const TILED_NS: [usize; 4] = [8, 16, 32, 64];

/// Batch sizes for the tiled-vs-dense virtualization sweep.
pub const TILED_BATCHES: [usize; 2] = [1, 64];

/// In-flight batch sizes for the remote-vs-in-process submit→wait sweep.
pub const REMOTE_BATCHES: [usize; 3] = [1, 8, 64];

/// Logical size of the in-situ fleet-DSPSA sweep (on 8×8 measured tiles).
pub const INSITU_N: usize = 16;

/// Square sizes for the kernel-dispatch GEMM grid. The n ≥ 8 rows carry
/// the PR-6 acceptance bar (≥2× median over the forced-scalar 4×4
/// reference on an AVX2 runner); 4 is the small-tile sanity row.
pub const KERNEL_NS: [usize; 4] = [4, 8, 16, 64];

/// Batch sizes for the kernel-dispatch GEMM grid.
pub const KERNEL_BATCHES: [usize; 3] = [1, 8, 64];

/// Batch sizes for the tracing-overhead sweep.
pub const TRACE_BATCHES: [usize; 2] = [1, 64];

/// Client counts for the concurrent-clients reactor sweep. 256 sits
/// above the soak job's 200-client floor, so the recorded trajectory
/// covers the same regime the CI concurrency gate pins.
pub const CONCURRENT_CLIENTS: [usize; 3] = [1, 32, 256];

/// Shard count for the sharded-vs-single serving comparison: one
/// single-replica loopback node per shard, so the recorded overhead is
/// pure scatter/gather cost (framing + N sockets + row placement).
pub const CLUSTER_SHARDS: usize = 3;

/// Batch sizes for the sharded-vs-single serving comparison.
pub const CLUSTER_BATCHES: [usize; 2] = [1, 16];

/// Run every perf bench; returns the report. Measures the batched
/// `apply_batch` path against the per-vector `matvec` loop it replaced
/// (written to `BENCH_pr1.json`; override with `RFNN_BENCH_OUT`), the
/// end-to-end `submit` → `Ticket::wait` serving path through the unified
/// front door (written to `BENCH_pr2.json`; override with
/// `RFNN_BENCH2_OUT`), the tiled `VirtualProcessor` execution against
/// the dense GEMM it virtualizes (written to `BENCH_pr3.json`; override
/// with `RFNN_BENCH3_OUT`), and the remote (loopback framed TCP) vs
/// in-process submit→wait latency sweep (written to `BENCH_pr4.json`;
/// override with `RFNN_BENCH4_OUT`), and the dispatched-vs-forced-scalar
/// kernel grid over `(n, batch)` (written to `BENCH_pr6.json`; override
/// with `RFNN_BENCH6_OUT`), and the sharded scatter/gather coordinator
/// vs the single-process apply it must match bit-for-bit (written to
/// `BENCH_pr7.json`; override with `RFNN_BENCH7_OUT`), and the tracing
/// overhead sweep — submit→wait under off/slow/all span-recording
/// policies (written to `BENCH_pr8.json`; override with
/// `RFNN_BENCH8_OUT`), and the concurrent-clients reactor front-end
/// sweep — pushed vs deferred/poll replies at 1/32/256 loopback
/// connections (written to `BENCH_pr10.json`; override with
/// `RFNN_BENCH10_OUT`) — so the perf trajectory tracks each PR. `tile`
/// is the physical tile size of the virtualization sweep.
pub fn all(quick: bool, tile: usize) -> String {
    let samples = if quick { 5 } else { 15 };
    let mut out = String::from("§Perf — hot-path micro-benchmarks\n");
    for stat in run_benches(samples) {
        out.push_str(&stat.line());
        out.push('\n');
    }
    out.push_str("§Perf — batched GEMM vs per-vector matvec (8×8 mesh)\n");
    let rows = run_batched_benches(samples);
    for (b, batched, pervec) in &rows {
        let speedup = pervec.median_ns() as f64 / batched.median_ns().max(1) as f64;
        out.push_str(&batched.line());
        out.push('\n');
        out.push_str(&pervec.line());
        out.push('\n');
        out.push_str(&format!("  batch {b:>3}: batched is {speedup:.2}× the per-vector loop\n"));
    }
    let json = batched_report_json(&rows, samples, quick);
    let path =
        std::env::var("RFNN_BENCH_OUT").unwrap_or_else(|_| "BENCH_pr1.json".to_string());
    match std::fs::write(&path, json.to_string_pretty()) {
        Ok(()) => out.push_str(&format!("wrote {path}\n")),
        Err(e) => out.push_str(&format!("could not write {path}: {e}\n")),
    }
    out.push_str("§Perf — end-to-end service submit→wait (MNIST infer, native backend)\n");
    let svc_rows = run_service_benches(samples);
    for (b, stats) in &svc_rows {
        out.push_str(&stats.line());
        out.push('\n');
        let per_req = stats.median_ns() as f64 / *b as f64;
        out.push_str(&format!(
            "  batch {b:>3}: {:.0} requests/s through the front door\n",
            1e9 / per_req.max(1.0)
        ));
    }
    let json2 = service_report_json(&svc_rows, samples, quick);
    let path2 =
        std::env::var("RFNN_BENCH2_OUT").unwrap_or_else(|_| "BENCH_pr2.json".to_string());
    match std::fs::write(&path2, json2.to_string_pretty()) {
        Ok(()) => out.push_str(&format!("wrote {path2}\n")),
        Err(e) => out.push_str(&format!("could not write {path2}: {e}\n")),
    }
    out.push_str(&format!(
        "§Perf — tiled VirtualProcessor vs dense GEMM ({tile}×{tile} tiles)\n"
    ));
    let tiled_rows = run_tiled_benches(samples, tile);
    for (n, b, dense, tiled) in &tiled_rows {
        out.push_str(&dense.line());
        out.push('\n');
        out.push_str(&tiled.line());
        out.push('\n');
        let ratio = tiled.median_ns() as f64 / dense.median_ns().max(1) as f64;
        out.push_str(&format!(
            "  n {n:>3} batch {b:>3}: tiled costs {ratio:.2}× the dense GEMM\n"
        ));
    }
    let json3 = tiled_report_json(&tiled_rows, samples, quick, tile);
    let path3 =
        std::env::var("RFNN_BENCH3_OUT").unwrap_or_else(|_| "BENCH_pr3.json".to_string());
    match std::fs::write(&path3, json3.to_string_pretty()) {
        Ok(()) => out.push_str(&format!("wrote {path3}\n")),
        Err(e) => out.push_str(&format!("could not write {path3}: {e}\n")),
    }
    out.push_str("§Perf — remote (loopback TCP) vs in-process submit→wait (MNIST infer)\n");
    let remote_rows = run_remote_benches(samples);
    for (b, local, remote) in &remote_rows {
        out.push_str(&local.line());
        out.push('\n');
        out.push_str(&remote.line());
        out.push('\n');
        let overhead = remote.median_ns() as f64 / local.median_ns().max(1) as f64;
        out.push_str(&format!(
            "  batch {b:>3}: remote submit→wait costs {overhead:.2}× the in-process path\n"
        ));
    }
    let json4 = remote_report_json(&remote_rows, samples, quick);
    let path4 =
        std::env::var("RFNN_BENCH4_OUT").unwrap_or_else(|_| "BENCH_pr4.json".to_string());
    match std::fs::write(&path4, json4.to_string_pretty()) {
        Ok(()) => out.push_str(&format!("wrote {path4}\n")),
        Err(e) => out.push_str(&format!("could not write {path4}: {e}\n")),
    }
    out.push_str(&format!(
        "§Perf — calibrated lowering + in-situ fleet DSPSA ({INSITU_N}×{INSITU_N} on 8×8 \
         measured tiles)\n"
    ));
    let (insitu_rows, fro_ideal, fro_cal) = run_insitu_benches(samples);
    for (mode, stats) in &insitu_rows {
        out.push_str(&stats.line());
        out.push('\n');
        let per_step = stats.median_ns() as f64 / INSITU_STEPS as f64;
        out.push_str(&format!(
            "  {}: {:.0} DSPSA steps/s in-situ (2 reprogram+measure evals per step, \
             amortized over {INSITU_STEPS}-step calls)\n",
            mode.name(),
            1e9 / per_step.max(1.0)
        ));
    }
    out.push_str(&format!(
        "  lowering: fro_error {fro_cal:.4e} calibrated vs {fro_ideal:.4e} nearest-ideal\n"
    ));
    let json5 = insitu_report_json(&insitu_rows, samples, quick, fro_ideal, fro_cal);
    let path5 =
        std::env::var("RFNN_BENCH5_OUT").unwrap_or_else(|_| "BENCH_pr5.json".to_string());
    match std::fs::write(&path5, json5.to_string_pretty()) {
        Ok(()) => out.push_str(&format!("wrote {path5}\n")),
        Err(e) => out.push_str(&format!("could not write {path5}: {e}\n")),
    }
    out.push_str("§Perf — dispatched GEMM kernel vs forced-scalar 4×4 reference\n");
    out.push_str(&format!("  {}\n", gemm::kernel_report()));
    let kernel_rows = run_kernel_benches(samples);
    for (n, b, active, scalar) in &kernel_rows {
        out.push_str(&active.line());
        out.push('\n');
        out.push_str(&scalar.line());
        out.push('\n');
        let speedup = scalar.median_ns() as f64 / active.median_ns().max(1) as f64;
        out.push_str(&format!(
            "  n {n:>3} batch {b:>3}: {} ({}) is {speedup:.2}× the scalar 4×4 reference\n",
            gemm::active().name(),
            gemm::micro_for(*n, *n, *b).label()
        ));
    }
    let json6 = kernel_report_json(&kernel_rows, samples, quick);
    let path6 =
        std::env::var("RFNN_BENCH6_OUT").unwrap_or_else(|_| "BENCH_pr6.json".to_string());
    match std::fs::write(&path6, json6.to_string_pretty()) {
        Ok(()) => out.push_str(&format!("wrote {path6}\n")),
        Err(e) => out.push_str(&format!("could not write {path6}: {e}\n")),
    }
    out.push_str(&format!(
        "§Perf — sharded scatter/gather vs single-process apply ({CLUSTER_SHARDS} loopback \
         shards)\n"
    ));
    let (cluster_rows, identical) = run_cluster_benches(samples);
    for (b, single, sharded) in &cluster_rows {
        out.push_str(&single.line());
        out.push('\n');
        out.push_str(&sharded.line());
        out.push('\n');
        let overhead = sharded.median_ns() as f64 / single.median_ns().max(1) as f64;
        out.push_str(&format!(
            "  batch {b:>3}: sharded scatter/gather costs {overhead:.2}× the single process\n"
        ));
    }
    out.push_str(&format!(
        "  sharded outputs bit-identical to the single process: {identical}\n"
    ));
    let json7 = cluster_report_json(&cluster_rows, samples, quick, identical);
    let path7 =
        std::env::var("RFNN_BENCH7_OUT").unwrap_or_else(|_| "BENCH_pr7.json".to_string());
    match std::fs::write(&path7, json7.to_string_pretty()) {
        Ok(()) => out.push_str(&format!("wrote {path7}\n")),
        Err(e) => out.push_str(&format!("could not write {path7}: {e}\n")),
    }
    out.push_str("§Perf — tracing overhead: submit→wait under off/slow/all policies\n");
    let trace_rows = run_trace_benches(samples);
    for (b, off, slow, all_on) in &trace_rows {
        out.push_str(&off.line());
        out.push('\n');
        out.push_str(&slow.line());
        out.push('\n');
        out.push_str(&all_on.line());
        out.push('\n');
        let s = slow.median_ns() as f64 / off.median_ns().max(1) as f64;
        let a = all_on.median_ns() as f64 / off.median_ns().max(1) as f64;
        out.push_str(&format!(
            "  batch {b:>3}: slow tracing costs {s:.2}× off, all costs {a:.2}× off\n"
        ));
    }
    let json8 = trace_report_json(&trace_rows, samples, quick);
    let path8 =
        std::env::var("RFNN_BENCH8_OUT").unwrap_or_else(|_| "BENCH_pr8.json".to_string());
    match std::fs::write(&path8, json8.to_string_pretty()) {
        Ok(()) => out.push_str(&format!("wrote {path8}\n")),
        Err(e) => out.push_str(&format!("could not write {path8}: {e}\n")),
    }
    out.push_str(
        "§Perf — reactor front end under concurrent clients (pushed vs deferred/poll)\n",
    );
    let (conc_rows, reactor_threads, batch_cap) = run_concurrent_benches(samples);
    for (c, pushed, deferred) in &conc_rows {
        out.push_str(&pushed.line());
        out.push('\n');
        out.push_str(&deferred.line());
        out.push('\n');
        let ratio = deferred.median_ns() as f64 / pushed.median_ns().max(1) as f64;
        out.push_str(&format!(
            "  clients {c:>3}: deferred/poll costs {ratio:.2}× the pushed reply path\n"
        ));
    }
    out.push_str(&format!(
        "  serving threads: {reactor_threads:.0} (1 reactor + fixed worker pool, flat across \
         the sweep); adaptive batch cap settled at {batch_cap:.0}\n"
    ));
    let json10 =
        concurrent_report_json(&conc_rows, samples, quick, reactor_threads, batch_cap);
    let path10 =
        std::env::var("RFNN_BENCH10_OUT").unwrap_or_else(|_| "BENCH_pr10.json".to_string());
    match std::fs::write(&path10, json10.to_string_pretty()) {
        Ok(()) => out.push_str(&format!("wrote {path10}\n")),
        Err(e) => out.push_str(&format!("could not write {path10}: {e}\n")),
    }
    out
}

/// Time the reactor front end under concurrent client load: `c` loopback
/// connections each carry one in-flight infer job (every submit is
/// written before any reply is drained), first with pushed replies
/// (`submit` → `RemoteTicket::wait`) and then through the deferred
/// poll-mode multiplex (`submit_deferred` → `wait_ticket`, which
/// round-trips `Job::Poll` frames), for each `c` in
/// [`CONCURRENT_CLIENTS`]. Returns `(clients, pushed, deferred)` stats
/// plus the serving process's post-sweep `reactor_threads` gauge and
/// adaptive `batch_cap` — the two observability fields the PR-10 record
/// pins so a run whose thread count scaled with its client count is
/// visibly tainted in the artifact trail.
pub fn run_concurrent_benches(
    samples: usize,
) -> (Vec<(usize, BenchStats, BenchStats)>, f64, f64) {
    let net = MnistRfnn::analog(8, MeshBackend::Ideal, 3);
    let bundle = ModelBundle::from_trained(&net).expect("analog net exports a bundle");
    let pool = ProcessorPool::new();
    pool.register(
        "mnist8",
        Workload::Mnist { bundle, backend: Backend::Native },
        PoolConfig {
            queue_depth: 4096,
            batch: BatchPolicy {
                max_batch: 256,
                max_wait: std::time::Duration::from_micros(200),
            },
            ..PoolConfig::default()
        },
    )
    .expect("register mnist8");
    let svc = Arc::new(ProcessorService::new(pool));
    let fe = TcpFrontEnd::bind(
        "127.0.0.1:0",
        Arc::new(Router::new(svc)),
        TcpConfig { max_connections: 512, ..TcpConfig::default() },
    )
    .expect("bind ephemeral loopback port");
    let addr = fe.local_addr().to_string();
    let img: Vec<f32> = (0..784).map(|i| (i % 61) as f32 / 61.0).collect();
    let mut out = Vec::new();
    for &c in &CONCURRENT_CLIENTS {
        let clients: Vec<RemoteClient> =
            (0..c).map(|_| RemoteClient::connect(&addr).expect("connect to loopback")).collect();
        let pushed = bench(&format!("reactor pushed   c{c}"), samples, || {
            let tickets: Vec<_> = clients
                .iter()
                .map(|cl| {
                    cl.submit(Job::Infer { processor: "mnist8".into(), image: img.clone() })
                        .expect("reactor accepts the frame")
                })
                .collect();
            for t in tickets {
                match t.wait().expect("served") {
                    JobResult::Infer { .. } => {}
                    other => panic!("unexpected result {other:?}"),
                }
            }
        });
        let deferred = bench(&format!("reactor deferred c{c}"), samples, || {
            let tickets: Vec<_> = clients
                .iter()
                .map(|cl| {
                    cl.submit_deferred(Job::Infer {
                        processor: "mnist8".into(),
                        image: img.clone(),
                    })
                    .expect("reactor accepts the frame")
                })
                .collect();
            for (cl, t) in clients.iter().zip(tickets) {
                match cl.wait_ticket(t).expect("served") {
                    JobResult::Infer { .. } => {}
                    other => panic!("unexpected result {other:?}"),
                }
            }
        });
        out.push((c, pushed, deferred));
    }
    let admin = RemoteClient::connect(&addr).expect("connect to loopback");
    let snapshot = match admin.admin(Admin::MetricsSnapshot).expect("metrics snapshot") {
        AdminReply::Metrics(json) => json,
        other => panic!("unexpected admin reply {other:?}"),
    };
    let reactor_threads = snapshot
        .get("transport")
        .and_then(|t| t.get("reactor_threads"))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    let batch_cap = snapshot.get("batch_cap").and_then(|v| v.as_f64()).unwrap_or(0.0);
    drop(admin);
    fe.shutdown();
    (out, reactor_threads, batch_cap)
}

/// The PR-10 perf-trajectory record for [`run_concurrent_benches`]: one
/// entry per (reply mode, client count) cell — `mode`/`batch` are the
/// perf gate's identity fields, `clients` the human-facing alias — plus
/// the serving process's own view of its thread budget (flat as clients
/// scale: the property the soak job asserts) and the load-adaptive batch
/// cap the sweep left behind.
pub fn concurrent_report_json(
    rows: &[(usize, BenchStats, BenchStats)],
    samples: usize,
    quick: bool,
    reactor_threads: f64,
    batch_cap: f64,
) -> Json {
    let mut results = Vec::new();
    for (c, pushed, deferred) in rows {
        let pn = pushed.median_ns() as f64 / *c as f64;
        let dn = deferred.median_ns() as f64 / *c as f64;
        results.push(Json::obj(vec![
            ("mode", Json::Str("pushed".into())),
            ("clients", Json::Num(*c as f64)),
            ("batch", Json::Num(*c as f64)),
            ("ns_per_request", Json::Num(pn)),
            ("requests_per_sec", Json::Num(1e9 / pn.max(1.0))),
        ]));
        results.push(Json::obj(vec![
            ("mode", Json::Str("deferred".into())),
            ("clients", Json::Num(*c as f64)),
            ("batch", Json::Num(*c as f64)),
            ("ns_per_request", Json::Num(dn)),
            ("requests_per_sec", Json::Num(1e9 / dn.max(1.0))),
            ("deferred_over_pushed", Json::Num(dn / pn.max(1.0))),
        ]));
    }
    Json::obj(vec![
        ("pr", Json::Num(10.0)),
        ("bench", Json::Str("concurrent_clients_reactor_front_end".into())),
        ("wire_version", Json::Num(WIRE_VERSION as f64)),
        ("transport", Json::Str("tcp_loopback_framed".into())),
        ("max_connections", Json::Num(512.0)),
        ("reactor_threads", Json::Num(reactor_threads)),
        ("batch_cap", Json::Num(batch_cap)),
        ("n", Json::Num(8.0)),
        ("samples", Json::Num(samples as f64)),
        ("quick", Json::Bool(quick)),
        ("results", Json::Arr(results)),
    ])
}

/// Time the end-to-end submit→wait serving path under each tracing
/// regime — no context (the `RFNN_TRACE=off` fast path), `slow` (the
/// default: context created, spans recorded, trace dropped at finish
/// unless the request beat the slow threshold), and `all` (every trace
/// retained in the global ring) — at each batch size in
/// [`TRACE_BATCHES`]. Policies are latched per-context through
/// [`TraceCtx::start_with`](crate::obs::trace::TraceCtx::start_with),
/// never through the global env knob, so concurrent tests keep theirs.
/// Returns `(batch, off, slow, all)` stats.
pub fn run_trace_benches(
    samples: usize,
) -> Vec<(usize, BenchStats, BenchStats, BenchStats)> {
    use crate::obs::trace::{Policy, TraceCtx, DEFAULT_SLOW_US};
    let net = MnistRfnn::analog(8, MeshBackend::Ideal, 3);
    let bundle = ModelBundle::from_trained(&net).expect("analog net exports a bundle");
    let pool = ProcessorPool::new();
    pool.register(
        "mnist8",
        Workload::Mnist { bundle, backend: Backend::Native },
        PoolConfig {
            queue_depth: 4096,
            batch: BatchPolicy {
                max_batch: 256,
                max_wait: std::time::Duration::from_micros(200),
            },
            ..PoolConfig::default()
        },
    )
    .expect("register mnist8");
    let svc = ProcessorService::new(pool);
    let img: Vec<f32> = (0..784).map(|i| (i % 61) as f32 / 61.0).collect();
    let sweep = |label: &str, b: usize, policy: Option<Policy>| {
        bench(label, samples, || {
            let pending: Vec<_> = (0..b)
                .map(|_| {
                    let ctx = policy.and_then(|p| TraceCtx::start_with(p, "bench.request"));
                    let t = svc
                        .submit_traced(
                            Job::Infer { processor: "mnist8".into(), image: img.clone() },
                            ctx.clone(),
                        )
                        .expect("queue depth exceeds max in-flight");
                    (t, ctx)
                })
                .collect();
            for (t, ctx) in pending {
                match t.wait().expect("worker alive") {
                    JobResult::Infer { .. } => {}
                    other => panic!("unexpected result {other:?}"),
                }
                if let Some(ctx) = ctx {
                    let _ = ctx.finish(false);
                }
            }
        })
    };
    let mut out = Vec::new();
    for &b in &TRACE_BATCHES {
        let off = sweep(&format!("trace off  submit→wait b{b}"), b, None);
        let slow = sweep(
            &format!("trace slow submit→wait b{b}"),
            b,
            Some(Policy::Slow(DEFAULT_SLOW_US)),
        );
        let all_on = sweep(&format!("trace all  submit→wait b{b}"), b, Some(Policy::All));
        out.push((b, off, slow, all_on));
    }
    out
}

/// The PR-8 perf-trajectory record for [`run_trace_benches`] results:
/// per-request cost under each policy plus the overhead ratios against
/// the untraced path — the artifact that proves `off` and `slow` tracing
/// stay in the noise on the serving hot path.
pub fn trace_report_json(
    rows: &[(usize, BenchStats, BenchStats, BenchStats)],
    samples: usize,
    quick: bool,
) -> Json {
    let results: Vec<Json> = rows
        .iter()
        .map(|(b, off, slow, all_on)| {
            let on = off.median_ns() as f64 / *b as f64;
            let sn = slow.median_ns() as f64 / *b as f64;
            let an = all_on.median_ns() as f64 / *b as f64;
            Json::obj(vec![
                ("batch", Json::Num(*b as f64)),
                ("off_ns_per_request", Json::Num(on)),
                ("slow_ns_per_request", Json::Num(sn)),
                ("all_ns_per_request", Json::Num(an)),
                ("off_requests_per_sec", Json::Num(1e9 / on.max(1.0))),
                ("slow_over_off", Json::Num(sn / on.max(1.0))),
                ("all_over_off", Json::Num(an / on.max(1.0))),
            ])
        })
        .collect();
    Json::obj(vec![
        ("pr", Json::Num(8.0)),
        ("bench", Json::Str("tracing_overhead_submit_wait".into())),
        ("wire_version", Json::Num(WIRE_VERSION as f64)),
        ("n", Json::Num(8.0)),
        ("samples", Json::Num(samples as f64)),
        ("quick", Json::Bool(quick)),
        ("results", Json::Arr(results)),
    ])
}

/// Time [`ShardedProcessor::try_apply_batch`] — scatter over
/// [`CLUSTER_SHARDS`] single-replica loopback nodes, gather by row
/// placement — against the single-process [`VirtualProcessor`] serving
/// the identical compiled target, at each batch size in
/// [`CLUSTER_BATCHES`]. Returns `(batch, single, sharded)` stats plus
/// whether every sharded output matched the single-process one
/// bit-for-bit (the PR-7 acceptance property: the integration suite pins
/// it, and the bench re-checks it on every run it records).
pub fn run_cluster_benches(samples: usize) -> (Vec<(usize, BenchStats, BenchStats)>, bool) {
    let mut rng = Rng::new(0xC1A5);
    let n = 12usize;
    let target = CMat::from_fn(n, n, |_, _| C64::real(rng.normal()));
    let spec = PlanSpec::new(4, Fidelity::Quantized);
    let full = VirtualProcessor::compile(&target, &spec).expect("quantized compile");
    let shards = plan_shards(&target, &spec, CLUSTER_SHARDS).expect("plan 3 tile-row shards");
    let mut fronts = Vec::new();
    let mut replicas = Vec::new();
    for _ in 0..shards.len() {
        let svc = Arc::new(ProcessorService::new(ProcessorPool::new()));
        let fe =
            TcpFrontEnd::bind("127.0.0.1:0", Arc::new(Router::new(svc)), TcpConfig::default())
                .expect("bind ephemeral loopback port");
        replicas.push(vec![fe.local_addr().to_string()]);
        fronts.push(fe);
    }
    let sp = ShardedProcessor::deploy("bench", &shards, &replicas, ShardConfig::default())
        .expect("deploy shards over loopback");
    let mut identical = true;
    let mut out = Vec::new();
    for &b in &CLUSTER_BATCHES {
        let x = CMat::from_fn(n, b, |i, j| {
            C64::new(0.05 * i as f64 - 0.2 + 0.01 * j as f64, 0.02 * i as f64)
        });
        identical &= sp.try_apply_batch(&x).expect("healthy cluster") == full.apply_batch(&x);
        let single = bench(&format!("single  apply n{n} b{b}"), samples, || {
            std::hint::black_box(full.apply_batch(std::hint::black_box(&x)));
        });
        let sharded =
            bench(&format!("sharded apply n{n} b{b} s{CLUSTER_SHARDS}"), samples, || {
                std::hint::black_box(
                    sp.try_apply_batch(std::hint::black_box(&x)).expect("healthy cluster"),
                );
            });
        out.push((b, single, sharded));
    }
    drop(sp);
    for fe in fronts {
        fe.shutdown();
    }
    (out, identical)
}

/// The PR-7 perf-trajectory record for [`run_cluster_benches`] results.
/// `bit_identical` rides along with the timings so a perf run that ever
/// saw the scatter/gather path diverge from the single process is
/// visibly tainted in the artifact trail.
pub fn cluster_report_json(
    rows: &[(usize, BenchStats, BenchStats)],
    samples: usize,
    quick: bool,
    bit_identical: bool,
) -> Json {
    let results: Vec<Json> = rows
        .iter()
        .map(|(b, single, sharded)| {
            let sn = single.median_ns() as f64 / *b as f64;
            let shn = sharded.median_ns() as f64 / *b as f64;
            Json::obj(vec![
                ("batch", Json::Num(*b as f64)),
                ("single_ns_per_vector", Json::Num(sn)),
                ("sharded_ns_per_vector", Json::Num(shn)),
                ("sharded_vectors_per_sec", Json::Num(1e9 / shn.max(1.0))),
                ("sharded_over_single", Json::Num(shn / sn.max(1.0))),
            ])
        })
        .collect();
    Json::obj(vec![
        ("pr", Json::Num(7.0)),
        ("bench", Json::Str("sharded_scatter_gather_vs_single".into())),
        ("wire_version", Json::Num(WIRE_VERSION as f64)),
        ("shards", Json::Num(CLUSTER_SHARDS as f64)),
        ("replicas_per_shard", Json::Num(1.0)),
        ("n", Json::Num(12.0)),
        ("tile", Json::Num(4.0)),
        ("fidelity", Json::Str("quantized".into())),
        ("bit_identical", Json::Bool(bit_identical)),
        ("samples", Json::Num(samples as f64)),
        ("quick", Json::Bool(quick)),
        ("results", Json::Arr(results)),
    ])
}

/// Time the dispatched (autotuned) kernel against the forced scalar 4×4
/// reference over the `(n, batch)` grid. Both sides run through the raw
/// slice entry (`gemm_into_micro`), so the comparison isolates kernel
/// cost — no output reshaping or allocation on either side. Returns
/// `(n, batch, active, scalar)` stats.
pub fn run_kernel_benches(samples: usize) -> Vec<(usize, usize, BenchStats, BenchStats)> {
    let mut rng = Rng::new(0x6E66);
    let mut rows = Vec::new();
    for &n in &KERNEL_NS {
        let a: Vec<C64> = (0..n * n).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        for &b in &KERNEL_BATCHES {
            let x: Vec<C64> = (0..n * b).map(|_| C64::new(rng.normal(), rng.normal())).collect();
            let mut out = vec![C64::ZERO; n * b];
            let micro = gemm::micro_for(n, n, b);
            let active = bench(&format!("gemm {n}x{n}x{b} {}", micro.label()), samples, || {
                gemm::gemm_into_micro(
                    micro,
                    std::hint::black_box(&a),
                    std::hint::black_box(&x),
                    &mut out,
                    n,
                    n,
                    b,
                );
                std::hint::black_box(&mut out);
            });
            let scalar = bench(&format!("gemm {n}x{n}x{b} scalar4x4 ref"), samples, || {
                gemm::gemm_into_micro(
                    Micro::Scalar { mr: 4, nr: 4 },
                    std::hint::black_box(&a),
                    std::hint::black_box(&x),
                    &mut out,
                    n,
                    n,
                    b,
                );
                std::hint::black_box(&mut out);
            });
            rows.push((n, b, active, scalar));
        }
    }
    rows
}

/// The PR-6 perf-trajectory record for [`run_kernel_benches`]: one entry
/// per `(n, batch)` cell with the dispatched kernel, its autotuned
/// `mr/nr` block shape, and both latencies. `kernel` is a gate key field,
/// so runs on differently-capable machines never compare against each
/// other. `speedup_median_n8` is the acceptance number: median speedup
/// over the n ≥ 8 cells.
pub fn kernel_report_json(
    rows: &[(usize, usize, BenchStats, BenchStats)],
    samples: usize,
    quick: bool,
) -> Json {
    let results: Vec<Json> = rows
        .iter()
        .map(|(n, b, active, scalar)| {
            let micro = gemm::micro_for(*n, *n, *b);
            let (mr, nr) = micro.dims();
            let act = active.median_ns() as f64;
            let sca = scalar.median_ns() as f64;
            Json::obj(vec![
                ("kernel", Json::Str(gemm::active().name().into())),
                ("micro", Json::Str(micro.label())),
                ("mr", Json::Num(mr as f64)),
                ("nr", Json::Num(nr as f64)),
                ("n", Json::Num(*n as f64)),
                ("batch", Json::Num(*b as f64)),
                ("active_ns_per_call", Json::Num(act)),
                ("scalar_ns_per_call", Json::Num(sca)),
                ("speedup_vs_scalar", Json::Num(sca / act.max(1.0))),
            ])
        })
        .collect();
    let mut speedups: Vec<f64> = rows
        .iter()
        .filter(|(n, ..)| *n >= 8)
        .map(|(_, _, active, scalar)| {
            scalar.median_ns() as f64 / active.median_ns().max(1) as f64
        })
        .collect();
    speedups.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_n8 = speedups.get(speedups.len() / 2).copied().unwrap_or(0.0);
    Json::obj(vec![
        ("pr", Json::Num(6.0)),
        ("bench", Json::Str("gemm_kernel_grid".into())),
        ("kernel", Json::Str(gemm::active().name().into())),
        ("policy", Json::Str(gemm::policy().name().into())),
        ("avx2_available", Json::Bool(gemm::avx2_available())),
        ("par_threshold_macs", Json::Num(gemm::par_threshold_macs() as f64)),
        ("samples", Json::Num(samples as f64)),
        ("quick", Json::Bool(quick)),
        ("results", Json::Arr(results)),
        ("speedup_median_n8", Json::Num(median_n8)),
    ])
}

/// Steps per timed `train_states` call in the in-situ sweep: enough for
/// the round-robin schedule to cycle every tile of the 2×2 fleet and to
/// amortize the call's bookkeeping (initial-loss measurement, optimizer
/// construction, final rounded-iterate check + best-code reprogram) to
/// ~20% of the recorded per-step cost.
pub const INSITU_STEPS: usize = 10;

/// Time in-situ DSPSA (2 reprogram+measure loss evaluations per step,
/// [`INSITU_STEPS`] steps per timed call) per perturbation mode on a
/// calibrated Measured fleet, and report the calibrated vs nearest-ideal
/// lowering errors for the same target. Returns
/// `(per-mode stats, fro_error nearest-ideal, fro_error calibrated)`.
pub fn run_insitu_benches(samples: usize) -> (Vec<(PerturbMode, BenchStats)>, f64, f64) {
    let mut rng = Rng::new(0xCA11);
    let sd = (2.0 / INSITU_N as f64).sqrt();
    let target = CMat::from_fn(INSITU_N, INSITU_N, |_, _| C64::real(rng.normal() * sd));
    let spec = PlanSpec::new(8, Fidelity::Measured);
    let fro_cal = VirtualProcessor::compile(&target, &spec).expect("measured compile")
        .plan()
        .fro_error;
    let fro_ideal = VirtualProcessor::compile(
        &target,
        &spec.with_calibration(Calibration::NearestIdeal),
    )
    .expect("measured compile")
    .plan()
    .fro_error;
    // 2 evals per step + 1 reserved for the final rounded-iterate check.
    let budget = 2 * INSITU_STEPS + 1;
    let mut rows = Vec::new();
    for mode in [PerturbMode::Monolithic, PerturbMode::BlockRoundRobin] {
        // Fresh fleet per mode (plan-cache hit: no re-synthesis).
        let mut vp = VirtualProcessor::compile(&target, &spec).expect("measured compile");
        let mut k = 0u64;
        let stats = bench(
            &format!("insitu dspsa {INSITU_STEPS}-step train ({}) n{INSITU_N}", mode.name()),
            samples,
            || {
                k += 1;
                std::hint::black_box(vp.train_states(
                    &target,
                    mode,
                    budget,
                    DspsaConfig::default(),
                    0xBE57 ^ k,
                ));
            },
        );
        rows.push((mode, stats));
    }
    (rows, fro_ideal, fro_cal)
}

/// The PR-5 perf-trajectory record for [`run_insitu_benches`] results.
pub fn insitu_report_json(
    rows: &[(PerturbMode, BenchStats)],
    samples: usize,
    quick: bool,
    fro_ideal: f64,
    fro_cal: f64,
) -> Json {
    let results: Vec<Json> = rows
        .iter()
        .map(|(mode, stats)| {
            // Each timed call runs INSITU_STEPS steps; the residual
            // per-call bookkeeping (~2 extra loss evals) is part of the
            // recorded amortized cost.
            let ns = stats.median_ns() as f64 / INSITU_STEPS as f64;
            Json::obj(vec![
                ("mode", Json::Str(mode.name().into())),
                ("ns_per_step", Json::Num(ns)),
                ("steps_per_sec", Json::Num(1e9 / ns.max(1.0))),
            ])
        })
        .collect();
    Json::obj(vec![
        ("pr", Json::Num(5.0)),
        ("bench", Json::Str("calibrated_lowering_insitu_dspsa".into())),
        ("n", Json::Num(INSITU_N as f64)),
        ("tile", Json::Num(8.0)),
        ("fidelity", Json::Str("measured".into())),
        ("samples", Json::Num(samples as f64)),
        ("quick", Json::Bool(quick)),
        ("steps_per_call", Json::Num(INSITU_STEPS as f64)),
        ("fro_error_nearest_ideal", Json::Num(fro_ideal)),
        ("fro_error_calibrated", Json::Num(fro_cal)),
        (
            "calibration_tighten_pct",
            Json::Num(100.0 * (fro_ideal - fro_cal) / fro_ideal.max(1e-300)),
        ),
        ("results", Json::Arr(results)),
    ])
}

/// One submit→wait sample of `b` in-flight infer jobs against anything
/// that implements [`JobSink`] — the in-process service and the TCP
/// client run the EXACT same code, so the recorded delta is pure
/// transport overhead (framing + JSON + socket + demux).
fn sink_sweep<S: JobSink>(
    sink: &S,
    label: &str,
    samples: usize,
    img: &[f32],
    b: usize,
) -> BenchStats {
    bench(label, samples, || {
        let pending: Vec<_> = (0..b)
            .map(|_| {
                sink.dispatch(Job::Infer { processor: "mnist8".into(), image: img.to_vec() })
                    .expect("queue depth exceeds max in-flight")
            })
            .collect();
        for p in pending {
            match p.wait_reply().expect("served") {
                JobResult::Infer { .. } => {}
                other => panic!("unexpected result {other:?}"),
            }
        }
    })
}

/// Time the full remote path — `RemoteClient::submit` → framed TCP over
/// loopback → router → worker → framed reply → `RemoteTicket::wait` —
/// against the in-process `ProcessorService` path serving the identical
/// workload, at each batch size in [`REMOTE_BATCHES`]. Returns
/// `(batch, local, remote)` stats.
pub fn run_remote_benches(samples: usize) -> Vec<(usize, BenchStats, BenchStats)> {
    let net = MnistRfnn::analog(8, MeshBackend::Ideal, 3);
    let bundle = ModelBundle::from_trained(&net).expect("analog net exports a bundle");
    let pool = ProcessorPool::new();
    pool.register(
        "mnist8",
        Workload::Mnist { bundle, backend: Backend::Native },
        PoolConfig {
            queue_depth: 4096,
            batch: BatchPolicy {
                max_batch: 256,
                max_wait: std::time::Duration::from_micros(200),
            },
            ..PoolConfig::default()
        },
    )
    .expect("register mnist8");
    let svc = Arc::new(ProcessorService::new(pool));
    let router = Arc::new(Router::new(svc.clone()));
    let fe = TcpFrontEnd::bind("127.0.0.1:0", router, TcpConfig::default())
        .expect("bind ephemeral loopback port");
    let client =
        RemoteClient::connect(&fe.local_addr().to_string()).expect("connect to loopback");
    let img: Vec<f32> = (0..784).map(|i| (i % 61) as f32 / 61.0).collect();
    let mut out = Vec::new();
    for &b in &REMOTE_BATCHES {
        let local =
            sink_sweep(svc.as_ref(), &format!("local  submit→wait b{b}"), samples, &img, b);
        let remote =
            sink_sweep(&client, &format!("remote submit→wait b{b}"), samples, &img, b);
        out.push((b, local, remote));
    }
    drop(client);
    fe.shutdown();
    out
}

/// The PR-4 perf-trajectory record for [`run_remote_benches`] results.
pub fn remote_report_json(
    rows: &[(usize, BenchStats, BenchStats)],
    samples: usize,
    quick: bool,
) -> Json {
    let results: Vec<Json> = rows
        .iter()
        .map(|(b, local, remote)| {
            let ln = local.median_ns() as f64 / *b as f64;
            let rn = remote.median_ns() as f64 / *b as f64;
            Json::obj(vec![
                ("batch", Json::Num(*b as f64)),
                ("local_ns_per_request", Json::Num(ln)),
                ("remote_ns_per_request", Json::Num(rn)),
                ("remote_requests_per_sec", Json::Num(1e9 / rn.max(1.0))),
                ("remote_over_local", Json::Num(rn / ln.max(1.0))),
            ])
        })
        .collect();
    Json::obj(vec![
        ("pr", Json::Num(4.0)),
        ("bench", Json::Str("remote_tcp_vs_local_submit_wait".into())),
        ("wire_version", Json::Num(WIRE_VERSION as f64)),
        ("transport", Json::Str("tcp_loopback_framed".into())),
        ("n", Json::Num(8.0)),
        ("samples", Json::Num(samples as f64)),
        ("quick", Json::Bool(quick)),
        ("results", Json::Arr(results)),
    ])
}

/// Time the tiled [`VirtualProcessor::apply_batch`] (digital tiles — pure
/// virtualization overhead, no device model) against the dense blocked
/// GEMM over the same `N×N` target, for each `N` in [`TILED_NS`] × batch
/// in [`TILED_BATCHES`]. Returns `(n, batch, dense, tiled)` stats.
pub fn run_tiled_benches(
    samples: usize,
    tile: usize,
) -> Vec<(usize, usize, BenchStats, BenchStats)> {
    let mut rng = Rng::new(0x71D3);
    let mut out = Vec::new();
    for &n in &TILED_NS {
        let target = CMat::from_fn(n, n, |_, _| C64::new(rng.normal(), rng.normal()));
        let vp = VirtualProcessor::compile(&target, &PlanSpec::new(tile, Fidelity::Digital))
            .expect("valid tile size");
        for &b in &TILED_BATCHES {
            let x = CMat::from_fn(n, b, |i, j| {
                C64::new(0.05 * i as f64 - 0.2 + 0.01 * j as f64, 0.02 * i as f64)
            });
            let dense = bench(&format!("dense gemm n{n} b{b}"), samples, || {
                std::hint::black_box(target.gemm(std::hint::black_box(&x)));
            });
            let tiled = bench(&format!("tiled t{tile} n{n} b{b}"), samples, || {
                std::hint::black_box(vp.apply_batch(std::hint::black_box(&x)));
            });
            out.push((n, b, dense, tiled));
        }
    }
    out
}

/// The PR-3 perf-trajectory record for [`run_tiled_benches`] results.
pub fn tiled_report_json(
    rows: &[(usize, usize, BenchStats, BenchStats)],
    samples: usize,
    quick: bool,
    tile: usize,
) -> Json {
    let results: Vec<Json> = rows
        .iter()
        .map(|(n, b, dense, tiled)| {
            let dn = dense.median_ns() as f64 / *b as f64;
            let tn = tiled.median_ns() as f64 / *b as f64;
            Json::obj(vec![
                ("n", Json::Num(*n as f64)),
                ("batch", Json::Num(*b as f64)),
                ("dense_ns_per_vector", Json::Num(dn)),
                ("tiled_ns_per_vector", Json::Num(tn)),
                ("tiled_vectors_per_sec", Json::Num(1e9 / tn.max(1.0))),
                ("tiled_over_dense", Json::Num(tn / dn.max(1.0))),
            ])
        })
        .collect();
    Json::obj(vec![
        ("pr", Json::Num(3.0)),
        ("bench", Json::Str("virtual_tiled_vs_dense_gemm".into())),
        ("tile", Json::Num(tile as f64)),
        ("fidelity", Json::Str("digital".into())),
        ("samples", Json::Num(samples as f64)),
        ("quick", Json::Bool(quick)),
        ("results", Json::Arr(results)),
    ])
}

/// Time the full serving path — `ProcessorService::submit` → batcher →
/// one `apply_batch` GEMM → `Ticket::wait` — at each in-flight batch size
/// in [`GEMM_BATCHES`]. Each sample submits `b` infer jobs and drains all
/// `b` tickets, so `median_ns / b` is the per-request front-door cost
/// including queueing, coalescing, and reply routing.
pub fn run_service_benches(samples: usize) -> Vec<(usize, BenchStats)> {
    let net = MnistRfnn::analog(8, MeshBackend::Ideal, 3);
    let bundle = ModelBundle::from_trained(&net).expect("analog net exports a bundle");
    let pool = ProcessorPool::new();
    pool.register(
        "mnist8",
        Workload::Mnist { bundle, backend: Backend::Native },
        PoolConfig {
            queue_depth: 4096,
            batch: BatchPolicy {
                max_batch: 256,
                max_wait: std::time::Duration::from_micros(200),
            },
            ..PoolConfig::default()
        },
    )
    .expect("register mnist8");
    let svc = ProcessorService::new(pool);
    let img: Vec<f32> = (0..784).map(|i| (i % 61) as f32 / 61.0).collect();
    let mut out = Vec::new();
    for &b in &GEMM_BATCHES {
        let stats = bench(&format!("service submit→wait b{b}"), samples, || {
            let tickets: Vec<_> = (0..b)
                .map(|_| {
                    svc.submit(Job::Infer { processor: "mnist8".into(), image: img.clone() })
                        .expect("queue depth exceeds max in-flight")
                })
                .collect();
            for t in tickets {
                match t.wait().expect("worker alive") {
                    JobResult::Infer { .. } => {}
                    other => panic!("unexpected result {other:?}"),
                }
            }
        });
        out.push((b, stats));
    }
    out
}

/// The PR-2 perf-trajectory record for [`run_service_benches`] results.
pub fn service_report_json(rows: &[(usize, BenchStats)], samples: usize, quick: bool) -> Json {
    let results: Vec<Json> = rows
        .iter()
        .map(|(b, stats)| {
            let per_req = stats.median_ns() as f64 / *b as f64;
            Json::obj(vec![
                ("batch", Json::Num(*b as f64)),
                ("ns_per_request", Json::Num(per_req)),
                ("requests_per_sec", Json::Num(1e9 / per_req.max(1.0))),
            ])
        })
        .collect();
    Json::obj(vec![
        ("pr", Json::Num(2.0)),
        ("bench", Json::Str("service_submit_wait_infer".into())),
        ("wire_version", Json::Num(WIRE_VERSION as f64)),
        ("n", Json::Num(8.0)),
        ("samples", Json::Num(samples as f64)),
        ("quick", Json::Bool(quick)),
        ("results", Json::Arr(results)),
    ])
}

/// Time `apply_batch` (one blocked GEMM per call) against the per-vector
/// loop the refactor replaced, at each batch size in [`GEMM_BATCHES`].
/// Returns `(batch, batched, per_vector)` stats; each sample times a full
/// batch, so per-vector cost is `median_ns / batch`.
///
/// The baseline deliberately reimplements the PRE-refactor kernel — a
/// direct row-dot `matvec` per vector, exactly the seed's hot loop — not
/// today's `matvec` (which now routes through the batch-1 GEMM), so the
/// recorded speedup measures the real before/after delta.
pub fn run_batched_benches(samples: usize) -> Vec<(usize, BenchStats, BenchStats)> {
    let mesh = DiscreteMesh::new(8, MeshBackend::Ideal);
    let m = crate::processor::LinearProcessor::matrix(&mesh).clone();
    let mut out = Vec::new();
    for &b in &GEMM_BATCHES {
        let x = CMat::from_fn(8, b, |i, j| {
            C64::new(0.05 * i as f64 - 0.2 + 0.01 * j as f64, 0.02 * i as f64)
        });
        let cols: Vec<Vec<C64>> = (0..b).map(|j| x.col(j)).collect();
        let batched = bench(&format!("mesh8.apply_batch b{b}"), samples, || {
            std::hint::black_box(mesh.apply_batch(std::hint::black_box(&x)));
        });
        let pervec = bench(&format!("mesh8 pre-PR matvec ×{b}"), samples, || {
            for c in &cols {
                let c = std::hint::black_box(c);
                let y: Vec<C64> = (0..m.rows())
                    .map(|i| m.row(i).iter().zip(c).map(|(&a, &b)| a * b).sum())
                    .collect();
                std::hint::black_box(y);
            }
        });
        out.push((b, batched, pervec));
    }
    out
}

/// The PR-1 perf-trajectory record for [`run_batched_benches`] results.
/// `samples`/`quick` are provenance — quick `cargo test` runs also write
/// the file, and the record says which mode produced it.
pub fn batched_report_json(
    rows: &[(usize, BenchStats, BenchStats)],
    samples: usize,
    quick: bool,
) -> Json {
    let results: Vec<Json> = rows
        .iter()
        .map(|(b, batched, pervec)| {
            let bv = batched.median_ns() as f64 / *b as f64;
            let pv = pervec.median_ns() as f64 / *b as f64;
            Json::obj(vec![
                ("batch", Json::Num(*b as f64)),
                ("batched_ns_per_vector", Json::Num(bv)),
                ("pervector_ns_per_vector", Json::Num(pv)),
                ("batched_vectors_per_sec", Json::Num(1e9 / bv.max(1.0))),
                ("pervector_vectors_per_sec", Json::Num(1e9 / pv.max(1.0))),
                ("speedup", Json::Num(pv / bv.max(1.0))),
            ])
        })
        .collect();
    let speedup_b64 = rows
        .iter()
        .find(|(b, ..)| *b == 64)
        .map(|(_, batched, pervec)| pervec.median_ns() as f64 / batched.median_ns().max(1) as f64)
        .unwrap_or(0.0);
    Json::obj(vec![
        ("pr", Json::Num(1.0)),
        ("bench", Json::Str("mesh8_apply_batch_vs_pervector".into())),
        ("n", Json::Num(8.0)),
        ("samples", Json::Num(samples as f64)),
        ("quick", Json::Bool(quick)),
        ("results", Json::Arr(results)),
        ("speedup_at_batch_64", Json::Num(speedup_b64)),
    ])
}

/// The individual benches (exposed for the bench binary).
pub fn run_benches(samples: usize) -> Vec<BenchStats> {
    let mut rng = Rng::new(0xBE7C);
    let mut results = Vec::new();

    // L3: mesh state recompose (DSPSA inner loop cost).
    let mut mesh = DiscreteMesh::new(8, MeshBackend::Ideal);
    let mut k = 0usize;
    results.push(bench("mesh8.set_state (recompose)", samples, || {
        k = (k + 1) % mesh.cells();
        mesh.set_state(k, State { theta: k % 6, phi: (k * 2) % 6 });
    }));

    // L3: mesh apply (per-sample hidden-layer matvec).
    let mesh = DiscreteMesh::new(8, MeshBackend::Ideal);
    let x: Vec<C64> = (0..8).map(|i| C64::new(0.1 * i as f64, 0.0)).collect();
    results.push(bench("mesh8.apply (complex matvec)", samples, || {
        std::hint::black_box(mesh.apply(std::hint::black_box(&x)));
    }));

    // L3: abs-detected batch apply.
    let xr: Vec<f64> = (0..8).map(|i| 0.2 * i as f64 - 0.5).collect();
    results.push(bench("mesh8.apply_abs", samples, || {
        std::hint::black_box(mesh.apply_abs(std::hint::black_box(&xr)));
    }));

    // L3: full native MNIST forward, batch 32.
    let net = MnistRfnn::analog(8, MeshBackend::Ideal, 1);
    let bundle = ModelBundle::from_trained(&net).unwrap();
    let img: Vec<f32> = (0..32 * 784).map(|i| ((i % 97) as f32) / 97.0).collect();
    results.push(bench("native fwd b32 (dense+mesh+dense)", samples, || {
        std::hint::black_box(bundle.forward_native(std::hint::black_box(&img), 32));
    }));

    // Math: SVD + decomposition (mesh programming cost).
    let a = CMat::from_fn(8, 8, |_, _| C64::new(rng.normal(), rng.normal()));
    results.push(bench("svd 8x8 complex", samples, || {
        std::hint::black_box(svd(std::hint::black_box(&a)));
    }));
    let f = svd(&a);
    let u = f.u.matmul(&f.vh);
    results.push(bench("decompose_unitary 8x8", samples, || {
        std::hint::black_box(decompose_unitary(std::hint::black_box(&u)));
    }));

    // Microwave: circuit-model evaluation (VNA sweep cost).
    let cell = crate::device::circuit::UnitCellCircuit::prototype();
    results.push(bench("unit-cell circuit sparams @f0", samples, || {
        std::hint::black_box(cell.sparams(2.0e9, State { theta: 3, phi: 1 }));
    }));

    // PJRT end-to-end (if artifacts present).
    let dir = crate::runtime::Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        if let Ok(mut engine) = crate::runtime::Engine::cpu(&dir) {
            let x32 = vec![0.1f32; 32 * 784];
            let args: Vec<Vec<f32>> = vec![
                x32,
                bundle.w1.clone(),
                bundle.b1.clone(),
                bundle.m_re.clone(),
                bundle.m_im.clone(),
                bundle.w2.clone(),
                bundle.b2.clone(),
            ];
            let arg_refs: Vec<&[f32]> = args.iter().map(|a| a.as_slice()).collect();
            // compile once
            let _ = engine.execute_f32("rfnn_mnist_fwd_b32", &arg_refs);
            results.push(bench("pjrt fwd b32 (dense kernel)", samples, || {
                std::hint::black_box(engine.execute_f32("rfnn_mnist_fwd_b32", &arg_refs).unwrap());
            }));
            // Ablation: the column-sweep kernel variant at b256.
            let x256 = vec![0.1f32; 256 * 784];
            let planes = mesh.coeff_planes();
            let sweep_args: Vec<Vec<f32>> = {
                let mut v = vec![x256.clone(), bundle.w1.clone(), bundle.b1.clone()];
                v.extend(planes.iter().cloned());
                v.push(bundle.w2.clone());
                v.push(bundle.b2.clone());
                v
            };
            let sweep_refs: Vec<&[f32]> = sweep_args.iter().map(|a| a.as_slice()).collect();
            if engine.execute_f32("rfnn_mnist_fwd_sweep_b256", &sweep_refs).is_ok() {
                results.push(bench("pjrt fwd b256 sweep (ablation)", samples.min(5), || {
                    std::hint::black_box(
                        engine.execute_f32("rfnn_mnist_fwd_sweep_b256", &sweep_refs).unwrap(),
                    );
                }));
            }
            let dense_args: Vec<Vec<f32>> = vec![
                x256,
                bundle.w1.clone(),
                bundle.b1.clone(),
                bundle.m_re.clone(),
                bundle.m_im.clone(),
                bundle.w2.clone(),
                bundle.b2.clone(),
            ];
            let dense_refs: Vec<&[f32]> = dense_args.iter().map(|a| a.as_slice()).collect();
            let _ = engine.execute_f32("rfnn_mnist_fwd_b256", &dense_refs);
            results.push(bench("pjrt fwd b256 dense (serving)", samples, || {
                std::hint::black_box(
                    engine.execute_f32("rfnn_mnist_fwd_b256", &dense_refs).unwrap(),
                );
            }));
        }
    }
    results
}

#[cfg(test)]
mod tests {
    #[test]
    fn perf_suite_runs_quick() {
        let report = super::all(true, 8);
        assert!(report.contains("mesh8.apply"), "{report}");
        assert!(report.contains("native fwd"), "{report}");
        assert!(report.contains("apply_batch"), "{report}");
        assert!(report.contains("service submit"), "{report}");
        assert!(report.contains("tiled t8"), "{report}");
        assert!(report.contains("remote submit"), "{report}");
        assert!(report.contains("insitu dspsa"), "{report}");
        assert!(report.contains("gemm kernel"), "{report}");
        assert!(report.contains("sharded apply"), "{report}");
        assert!(report.contains("bit-identical to the single process: true"), "{report}");
        assert!(report.contains("tracing overhead"), "{report}");
        assert!(report.contains("trace all"), "{report}");
        assert!(report.contains("reactor pushed"), "{report}");
        assert!(report.contains("reactor deferred"), "{report}");
    }

    #[test]
    fn concurrent_report_is_well_formed() {
        // Minimal samples: correctness of the record, not the timings.
        let (rows, reactor_threads, batch_cap) = super::run_concurrent_benches(2);
        assert_eq!(rows.len(), super::CONCURRENT_CLIENTS.len());
        // The reactor's thread budget must not scale with its client
        // count: 1 reactor + the default 4-worker pool, even at c=256.
        assert_eq!(reactor_threads, 5.0, "reactor + 4 default workers");
        assert!(batch_cap >= 1.0, "batch_cap {batch_cap}");
        let json =
            super::concurrent_report_json(&rows, 2, true, reactor_threads, batch_cap);
        let parsed = crate::util::json::parse(&json.to_string_pretty()).expect("valid JSON");
        assert_eq!(parsed.get("pr").and_then(|v| v.as_f64()), Some(10.0));
        assert_eq!(
            parsed.get("wire_version").and_then(|v| v.as_f64()),
            Some(super::WIRE_VERSION as f64)
        );
        assert_eq!(parsed.get("reactor_threads").and_then(|v| v.as_f64()), Some(5.0));
        let results = parsed.get("results").and_then(|r| r.as_arr()).expect("results");
        // One pushed + one deferred entry per client count.
        assert_eq!(results.len(), 2 * super::CONCURRENT_CLIENTS.len());
        for r in results {
            let mode = r.get("mode").and_then(|v| v.as_str()).expect("mode");
            assert!(mode == "pushed" || mode == "deferred", "mode {mode}");
            let ns = r.get("ns_per_request").and_then(|v| v.as_f64()).expect("ns");
            assert!(ns.is_finite() && ns > 0.0, "ns_per_request {ns}");
            let rps = r.get("requests_per_sec").and_then(|v| v.as_f64()).expect("rps");
            assert!(rps.is_finite() && rps > 0.0, "requests_per_sec {rps}");
        }
    }

    #[test]
    fn trace_report_is_well_formed() {
        // Minimal samples: correctness of the record, not the timings.
        let rows = super::run_trace_benches(2);
        assert_eq!(rows.len(), super::TRACE_BATCHES.len());
        let json = super::trace_report_json(&rows, 2, true);
        let parsed = crate::util::json::parse(&json.to_string_pretty()).expect("valid JSON");
        assert_eq!(parsed.get("pr").and_then(|v| v.as_f64()), Some(8.0));
        assert_eq!(
            parsed.get("wire_version").and_then(|v| v.as_f64()),
            Some(super::WIRE_VERSION as f64)
        );
        let results = parsed.get("results").and_then(|r| r.as_arr()).expect("results");
        assert_eq!(results.len(), super::TRACE_BATCHES.len());
        for r in results {
            for key in ["off_ns_per_request", "slow_ns_per_request", "all_ns_per_request"] {
                let ns = r.get(key).and_then(|v| v.as_f64()).expect(key);
                assert!(ns.is_finite() && ns > 0.0, "{key} {ns}");
            }
            for key in ["slow_over_off", "all_over_off"] {
                let ratio = r.get(key).and_then(|v| v.as_f64()).expect(key);
                assert!(ratio.is_finite() && ratio > 0.0, "{key} {ratio}");
            }
        }
    }

    #[test]
    fn cluster_report_is_well_formed() {
        // Minimal samples: correctness of the record, not the timings.
        let (rows, identical) = super::run_cluster_benches(2);
        assert_eq!(rows.len(), super::CLUSTER_BATCHES.len());
        // The acceptance property itself: row-placement gather over live
        // loopback shards reproduced the single-process bits.
        assert!(identical, "sharded outputs diverged from the single process");
        let json = super::cluster_report_json(&rows, 2, true, identical);
        let parsed = crate::util::json::parse(&json.to_string_pretty()).expect("valid JSON");
        assert_eq!(parsed.get("pr").and_then(|v| v.as_f64()), Some(7.0));
        assert_eq!(parsed.get("shards").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(
            parsed.get("wire_version").and_then(|v| v.as_f64()),
            Some(super::WIRE_VERSION as f64)
        );
        let results = parsed.get("results").and_then(|r| r.as_arr()).expect("results");
        assert_eq!(results.len(), super::CLUSTER_BATCHES.len());
        for r in results {
            let ratio =
                r.get("sharded_over_single").and_then(|v| v.as_f64()).expect("ratio");
            assert!(ratio.is_finite() && ratio > 0.0, "sharded_over_single {ratio}");
            let vps =
                r.get("sharded_vectors_per_sec").and_then(|v| v.as_f64()).expect("vps");
            assert!(vps.is_finite() && vps > 0.0, "sharded_vectors_per_sec {vps}");
        }
    }

    #[test]
    fn kernel_report_is_well_formed() {
        // Minimal samples: correctness of the record, not the timings.
        let rows = super::run_kernel_benches(2);
        assert_eq!(rows.len(), super::KERNEL_NS.len() * super::KERNEL_BATCHES.len());
        let json = super::kernel_report_json(&rows, 2, true);
        let parsed = crate::util::json::parse(&json.to_string_pretty()).expect("valid JSON");
        assert_eq!(parsed.get("pr").and_then(|v| v.as_f64()), Some(6.0));
        let kernel = parsed.get("kernel").and_then(|v| v.as_str()).expect("kernel");
        assert!(kernel == "scalar" || kernel == "avx2", "kernel {kernel}");
        let thr = parsed.get("par_threshold_macs").and_then(|v| v.as_f64()).expect("thr");
        assert!((4096.0..=1048576.0).contains(&thr), "par_threshold_macs {thr}");
        let results = parsed.get("results").and_then(|r| r.as_arr()).expect("results");
        assert_eq!(results.len(), rows.len());
        for r in results {
            let s = r.get("speedup_vs_scalar").and_then(|v| v.as_f64()).expect("speedup");
            assert!(s.is_finite() && s > 0.0, "speedup_vs_scalar {s}");
            let mr = r.get("mr").and_then(|v| v.as_f64()).expect("mr");
            assert!(mr >= 1.0, "mr {mr}");
        }
        let med = parsed.get("speedup_median_n8").and_then(|v| v.as_f64()).expect("median");
        assert!(med.is_finite() && med > 0.0, "speedup_median_n8 {med}");
    }

    #[test]
    fn insitu_report_is_well_formed() {
        // Minimal samples: correctness of the record, not the timings.
        let (rows, fro_ideal, fro_cal) = super::run_insitu_benches(2);
        assert_eq!(rows.len(), 2);
        // The lowering comparison is the calibration acceptance number:
        // nearest-measured must not be worse, and both must be finite.
        assert!(fro_cal.is_finite() && fro_ideal.is_finite());
        assert!(fro_cal <= fro_ideal + 1e-9, "calibrated {fro_cal} > ideal {fro_ideal}");
        let json = super::insitu_report_json(&rows, 2, true, fro_ideal, fro_cal);
        let parsed = crate::util::json::parse(&json.to_string_pretty()).expect("valid JSON");
        assert_eq!(parsed.get("pr").and_then(|v| v.as_f64()), Some(5.0));
        let tighten =
            parsed.get("calibration_tighten_pct").and_then(|v| v.as_f64()).expect("pct");
        assert!(tighten.is_finite() && tighten >= -1e-6, "tighten {tighten}");
        let results = parsed.get("results").and_then(|r| r.as_arr()).expect("results");
        assert_eq!(results.len(), 2);
        for r in results {
            let ns = r.get("ns_per_step").and_then(|v| v.as_f64()).expect("ns");
            assert!(ns.is_finite() && ns > 0.0, "ns_per_step {ns}");
            assert!(r.get("mode").is_some());
        }
    }

    #[test]
    fn remote_report_is_well_formed() {
        // Minimal samples: correctness of the record, not the timings.
        let rows = super::run_remote_benches(2);
        assert_eq!(rows.len(), super::REMOTE_BATCHES.len());
        let json = super::remote_report_json(&rows, 2, true);
        let parsed = crate::util::json::parse(&json.to_string_pretty()).expect("valid JSON");
        assert_eq!(
            parsed.get("wire_version").and_then(|v| v.as_f64()),
            Some(super::WIRE_VERSION as f64)
        );
        let results = parsed.get("results").and_then(|r| r.as_arr()).expect("results");
        assert_eq!(results.len(), super::REMOTE_BATCHES.len());
        for r in results {
            let ratio = r.get("remote_over_local").and_then(|v| v.as_f64()).expect("ratio");
            assert!(ratio.is_finite() && ratio > 0.0, "remote_over_local {ratio}");
            let rps =
                r.get("remote_requests_per_sec").and_then(|v| v.as_f64()).expect("rps");
            assert!(rps.is_finite() && rps > 0.0, "remote_requests_per_sec {rps}");
        }
    }

    #[test]
    fn tiled_report_is_well_formed() {
        // Minimal samples: correctness of the record, not the timings.
        let rows = super::run_tiled_benches(2, 4);
        assert_eq!(rows.len(), super::TILED_NS.len() * super::TILED_BATCHES.len());
        let json = super::tiled_report_json(&rows, 2, true, 4);
        let parsed = crate::util::json::parse(&json.to_string_pretty()).expect("valid JSON");
        assert_eq!(parsed.get("tile").and_then(|v| v.as_f64()), Some(4.0));
        let results = parsed.get("results").and_then(|r| r.as_arr()).expect("results");
        assert_eq!(results.len(), rows.len());
        for r in results {
            let ratio = r.get("tiled_over_dense").and_then(|v| v.as_f64()).expect("ratio");
            assert!(ratio.is_finite() && ratio > 0.0, "tiled_over_dense {ratio}");
        }
    }

    #[test]
    fn service_report_is_well_formed() {
        // Minimal samples: correctness of the record, not the timings.
        let rows = super::run_service_benches(2);
        assert_eq!(rows.len(), super::GEMM_BATCHES.len());
        let json = super::service_report_json(&rows, 2, true);
        let parsed = crate::util::json::parse(&json.to_string_pretty()).expect("valid JSON");
        assert_eq!(
            parsed.get("wire_version").and_then(|v| v.as_f64()),
            Some(super::WIRE_VERSION as f64)
        );
        let results = parsed.get("results").and_then(|r| r.as_arr()).expect("results");
        assert_eq!(results.len(), super::GEMM_BATCHES.len());
        for r in results {
            let rps = r.get("requests_per_sec").and_then(|v| v.as_f64()).expect("rps");
            assert!(rps.is_finite() && rps > 0.0, "requests_per_sec {rps}");
        }
    }

    #[test]
    fn batched_report_is_well_formed() {
        // Minimal samples: correctness of the record, not the timings.
        let rows = super::run_batched_benches(3);
        assert_eq!(rows.len(), super::GEMM_BATCHES.len());
        let json = super::batched_report_json(&rows, 3, true);
        let parsed = crate::util::json::parse(&json.to_string_pretty()).expect("valid JSON");
        let results = parsed.get("results").and_then(|r| r.as_arr()).expect("results");
        assert_eq!(results.len(), super::GEMM_BATCHES.len());
        for r in results {
            let s = r.get("speedup").and_then(|v| v.as_f64()).expect("speedup");
            assert!(s.is_finite() && s > 0.0, "speedup {s}");
        }
        assert!(parsed.get("speedup_at_batch_64").and_then(|v| v.as_f64()).unwrap() > 0.0);
    }
}
