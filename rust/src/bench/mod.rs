//! The paper-experiment harness: one regenerator per table and figure in
//! the evaluation (DESIGN.md §5 maps experiment ids to modules), plus the
//! §Perf micro-benchmarks.
//!
//! `criterion` is unavailable offline, so [`harness`] provides warmup +
//! repeated timing with percentile statistics; `rust/benches/
//! paper_benches.rs` (harness = false) and the `rfnn bench` CLI both call
//! into this module.

pub mod ablate;
pub mod figures;
pub mod harness;
pub mod mnist_exp;
pub mod perf;
pub mod table2;

/// An experiment produces a human-readable report (the paper's rows).
pub type Report = String;

/// All experiment names, in paper order.
pub const EXPERIMENTS: &[&str] = &[
    "table1", "fig3", "fig5", "fig6", "fig8", "fig9", "fig10", "fig12", "fig15", "fig16",
    "table2", "ablate", "perf",
];

/// Experiment options beyond the name.
#[derive(Clone, Copy, Debug, Default)]
pub struct BenchOpts {
    /// Shrink workloads (CI mode).
    pub quick: bool,
    /// Physical tile size for the perf tiled-vs-dense sweep (`rfnn bench
    /// perf --tile T`); `None` uses the paper's 8×8 processor size.
    pub tile: Option<usize>,
}

/// Run one experiment by name. `quick` shrinks workloads (CI mode).
pub fn run(name: &str, quick: bool) -> Result<Report, String> {
    run_opts(name, &BenchOpts { quick, tile: None })
}

/// [`run`] with explicit options.
pub fn run_opts(name: &str, opts: &BenchOpts) -> Result<Report, String> {
    let quick = opts.quick;
    match name {
        "table1" => Ok(figures::table1()),
        "fig3" => Ok(figures::fig3()),
        "fig5" => Ok(figures::fig5(quick)),
        "fig6" => Ok(figures::fig6()),
        "fig8" => Ok(figures::fig8()),
        "fig9" => Ok(figures::fig9(quick)),
        "fig10" => Ok(figures::fig10(quick)),
        "fig12" => Ok(figures::fig12(quick)),
        "fig15" => Ok(mnist_exp::fig15(quick)),
        "fig16" => Ok(mnist_exp::fig16(quick)),
        "table2" => Ok(table2::table2()),
        "ablate" => Ok(ablate::all(quick)),
        "perf" => Ok(perf::all(quick, opts.tile.unwrap_or(8))),
        other => Err(format!("unknown experiment '{other}' (have: {EXPERIMENTS:?})")),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn registry_covers_all_names() {
        for name in super::EXPERIMENTS {
            // Don't run the heavy ones here; just check dispatch exists by
            // rejecting unknown names.
            assert!(super::run("definitely-not-an-experiment", true).is_err());
            assert!(super::EXPERIMENTS.contains(name));
        }
    }
}
