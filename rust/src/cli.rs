//! The `rfnn` command layer: hand-rolled argument parsing (the offline
//! vendor set has no clap) plus the command implementations the binary
//! dispatches to.
//!
//! Grammar: `rfnn <command> [--flag[=value] | --flag value | positional]…`
//!
//! `serve` and `job` speak the unified serving API: both register a
//! default [`ProcessorPool`] (an MNIST bundle, a 2×2 classifier bank, and
//! a bare 8×8 mesh). `job` dispatches its wire document through the
//! shared [`Router`] path (`submit_wire` → `wait`), `serve --listen`
//! puts the same router behind the framed-TCP front end, and `client`
//! drives a remote server with [`RemoteClient`] — all speaking the
//! versioned wire form ([`crate::coordinator::service::WIRE_VERSION`]).

use crate::bench;
use crate::compiler::{
    plan_shards, Calibration, Compiler, PerturbMode, PlanSpec, VirtualProcessor, VALID_TILES,
};
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::router::{
    Admin, AdminReply, Endpoint, Router, RouterError, TRACE_DUMP_DEFAULT,
};
use crate::coordinator::server::{Backend, ModelBundle};
use crate::coordinator::service::{
    Job, JobResult, PoolConfig, ProcessorPool, ProcessorService, SubmitError, Workload,
};
use crate::coordinator::sharded::{ShardConfig, ShardedProcessor};
use crate::coordinator::transport::{RemoteClient, TcpConfig, TcpFrontEnd};
use crate::dataset::mnist::load_or_synthesize;
use crate::device::State;
use crate::math::c64::C64;
use crate::math::cmat::CMat;
use crate::math::rng::Rng;
use crate::mesh::propagate::{DiscreteMesh, MeshBackend};
use crate::nn::rfnn2x2::{PostParams, Rfnn2x2};
use crate::nn::rfnn_mnist::{MnistRfnn, MnistTrainConfig};
use crate::nn::sgd::SgdConfig;
use crate::processor::{Fidelity, LinearProcessor};
use crate::runtime::Manifest;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (the subcommand).
    pub command: Option<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` / bare `--key` (value "true").
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Flag as string.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// Flag parsed to any `FromStr`, with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Boolean flag (present and not "false").
    pub fn is_set(&self, key: &str) -> bool {
        self.get(key).map(|v| v != "false").unwrap_or(false)
    }
}

const USAGE: &str = "\
rfnn — reconfigurable linear RF analog processor / microwave neural network

USAGE:
    rfnn bench <experiment|all> [--quick] [--tile T]   regenerate a paper table/figure
    rfnn train-mnist [--train N] [--test N] [--epochs N] [--lr F] [--digital]
    rfnn serve [--requests N] [--batch N] [--depth N] [--native]
               [--tile T] [--fidelity F] [--listen ADDR] [--minimal]
    rfnn job '<wire json>' [--native] [--tile T]       submit one wire-encoded job
    rfnn client [--connect ADDR] job '<wire json>'     submit to a remote server
    rfnn client [--connect ADDR] admin <health|metrics|processors|cluster|trace|shutdown>
                [--format prom] [--n N]
    rfnn cluster plan   [--rows M] [--cols N] [--tile T] [--fidelity F] [--seed S]
                        [--fab-seed S] [--calibration measured|ideal] [--shards N]
    rfnn cluster deploy --nodes A,B,C [--replicas R] [--name NAME] [plan flags]
    rfnn cluster serve  --nodes A,B,C [--replicas R] [--requests N] [--batch B]
                        [plan flags]
    rfnn compile [--rows M] [--cols N] [--tile T] [--fidelity F] [--seed S]
                 [--fab-seed S] [--calibration measured|ideal]
                 [--train EVALS] [--dspsa-mode monolithic|block|block-random]
                 [--dspsa-seed S]
    rfnn lint [--rule NAME] [--format json|text] [--root DIR]
                                                       in-repo static analysis pass
    rfnn info                                          platform + artifact status

Every command also takes --kernel auto|scalar|avx2 (default auto), the
CLI spelling of the RFNN_KERNEL env knob: it pins the complex-GEMM
microkernel the runtime dispatcher may select (scalar forces the
portable reference path; avx2 falls back to scalar when the CPU lacks
AVX2+FMA). `rfnn info` reports which kernel is active.

serve drives the pooled ProcessorService (mnist8 + cls2x2 + mesh8) with
mixed infer/classify/raw-apply/reprogram traffic; --depth bounds each
admission queue (overload sheds, it does not block). --tile T additionally
registers 'virt8' — the MNIST hidden stage virtualized over a fleet of
T×T tiles by the tiling compiler — and routes part of the infer traffic
through it. With --listen ADDR (e.g. 127.0.0.1:7878; port 0 picks an
ephemeral port) serve instead starts the framed-TCP front end over the
same pool and runs until `rfnn client admin shutdown`.

client speaks the same versioned wire protocol over TCP: `client job`
submits one job document (a compile job can register a new virtual
processor on the running server), `client admin` drives the control
plane (`admin cluster` prints the per-shard health map of an installed
sharded coordinator). Default --connect is 127.0.0.1:7878.

serve --minimal (requires --listen) starts a BARE node: an empty pool
behind the TCP front end, populated over the wire by compile /
shard_compile jobs — the shape `cluster deploy` expects of its nodes.
With RFNN_AUTH_TOKEN set, serve requires every connection's first frame
to present that token, and client/cluster send it automatically.

Observability: RFNN_TRACE=off|slow|ratio:N|all (default slow, threshold
RFNN_TRACE_SLOW_US µs) selects which completed request traces the server
retains; `client admin trace --n N` dumps the last N as span trees, and
traces stitch across cluster nodes. `client admin metrics --format prom`
prints the metrics snapshot in Prometheus text exposition. RFNN_LOG=
off|error|warn|info|debug sets the JSON-lines log level on stderr.

cluster shards one seeded random M×N weight matrix across serving
nodes: `plan` prints the tile-row split, `deploy` registers each
shard's slice (replicated --replicas times, round-robin over --nodes)
and probes the composed matrix, and `serve` then drives random batches
through the scatter/gather coordinator, checking every output
bit-for-bit against a local single-process compile of the same seeded
target. All processes derive the target from (--rows --cols --seed),
so plan/deploy/serve agree without shipping weights out of band.

compile lowers a seeded random M×N weight matrix onto T×T physical tiles
and prints the plan (tile grid, per-tile states/scales/errors, reprogram
cost, plan-cache behavior). Fidelities: digital ideal quantized measured.
At measured fidelity the lowering is calibration-aware by default: each
cell's discrete state is chosen against the tile's *measured* device
blocks (virtual-VNA tables cached by fab seed), and the report compares
the resulting fro_error against nearest-ideal snapping
(--calibration ideal forces the uncalibrated rule). --train EVALS then
runs in-situ DSPSA over the fleet's states against the same target
within that evaluation budget; --dspsa-mode picks monolithic flat-code
perturbation or block-coordinate (one tile per step, round-robin or
random).

lint runs the in-repo static-analysis pass over rust/src/**/*.rs and
Cargo.toml, mechanizing the standing contracts (rule IDs: wire-cast
log-discipline unsafe-hygiene panic-serving determinism zero-dep).
--rule restricts to one rule, --format json emits the machine-readable
report CI consumes; intentional exceptions carry an inline
`// rfnn-lint: allow(<rule>)` justification in the source.

EXPERIMENTS: table1 fig3 fig5 fig6 fig8 fig9 fig10 fig12 fig15 fig16 table2 perf";

/// Dispatch a parsed command line; returns the process exit code.
pub fn run(args: &Args) -> i32 {
    // `--kernel` mirrors the `RFNN_KERNEL` env knob (CLI wins): it must
    // be applied before ANY gemm runs, because the dispatcher latches the
    // policy in a process-wide `OnceLock` on first use.
    if let Some(k) = args.get("kernel") {
        match k {
            "auto" | "scalar" | "avx2" => std::env::set_var("RFNN_KERNEL", k),
            _ => {
                eprintln!("unknown kernel '{k}' (have: auto scalar avx2)");
                return 2;
            }
        }
    }
    match args.command.as_deref() {
        Some("bench") => cmd_bench(args),
        Some("train-mnist") => cmd_train(args),
        Some("serve") => cmd_serve(args),
        Some("job") => cmd_job(args),
        Some("client") => cmd_client(args),
        Some("cluster") => cmd_cluster(args),
        Some("compile") => cmd_compile(args),
        Some("lint") => cmd_lint(args),
        Some("info") => cmd_info(),
        _ => {
            println!("{USAGE}");
            0
        }
    }
}

/// Parse a fidelity name (`--fidelity digital|ideal|quantized|measured`) —
/// the shared wire/CLI spelling.
fn parse_fidelity(name: &str) -> Option<Fidelity> {
    Fidelity::from_name(name)
}

fn cmd_bench(args: &Args) -> i32 {
    let tile = match args.get("tile") {
        None => None,
        Some(v) => match v.parse::<usize>() {
            Ok(t) if VALID_TILES.contains(&t) => Some(t),
            _ => {
                eprintln!("--tile {v} is not a physical tile size (have {VALID_TILES:?})");
                return 2;
            }
        },
    };
    let opts = bench::BenchOpts { quick: args.is_set("quick"), tile };
    let target = args.positional.first().map(String::as_str).unwrap_or("all");
    let names: Vec<&str> = if target == "all" {
        bench::EXPERIMENTS.to_vec()
    } else {
        vec![target]
    };
    for name in names {
        println!("=== {name} ===");
        match bench::run_opts(name, &opts) {
            Ok(report) => println!("{report}"),
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        }
    }
    0
}

fn cmd_train(args: &Args) -> i32 {
    let n_train = args.get_or("train", 2000usize);
    let n_test = args.get_or("test", 1000usize);
    let epochs = args.get_or("epochs", 30usize);
    let lr = args.get_or("lr", 0.02f64);
    let seed = args.get_or("seed", 2023u64);
    let (tr, te) = load_or_synthesize(n_train, n_test, seed);
    let cfg = MnistTrainConfig {
        epochs,
        sgd: SgdConfig { lr, batch_size: 10, momentum: 0.0 },
        ..Default::default()
    };
    let mut net = if args.is_set("digital") {
        println!("training digital twin ({n_train} samples, {epochs} epochs, lr {lr})");
        MnistRfnn::digital(8, seed)
    } else {
        println!("training analog RFNN ({n_train} samples, {epochs} epochs, lr {lr})");
        MnistRfnn::analog(8, MeshBackend::Measured { base_seed: seed ^ 0xAA }, seed)
    };
    net.train(&tr, &cfg);
    for h in net.history.iter().step_by((epochs / 10).max(1)) {
        println!("epoch {:>3}: train acc {:.3} err {:.3}", h.epoch + 1, h.train_acc, h.train_loss);
    }
    println!("test accuracy: {:.2}%", 100.0 * net.test_accuracy(&te));
    0
}

/// The six demo 2×2 classifiers (fixed post-params; one per θ state) —
/// enough to exercise state-grouped serving without a training pass.
/// Public so the service tests and the CLI serve EXACTLY the same bank.
pub fn demo_classifiers() -> Vec<Rfnn2x2> {
    (0..6)
        .map(|theta| Rfnn2x2 {
            state: State { theta, phi: 5 },
            post: PostParams { w1: 0.9 - 0.1 * theta as f64, w2: -0.5, b: 0.2 },
            gamma: 0.01,
            h_scale: 1.0,
        })
        .collect()
}

/// Build the default three-processor pool: `mnist8` (MNIST bundle over
/// the requested backend), `cls2x2` (classifier bank), `mesh8` (bare
/// ideal mesh serving raw applies and reprograms). With `virt:
/// Some((tile, fidelity))` a fourth processor `virt8` serves the same
/// MNIST model with its hidden stage virtualized over a `tile`-size
/// fleet by the tiling compiler.
fn default_pool(
    backend: Backend,
    cfg: PoolConfig,
    virt: Option<(usize, Fidelity)>,
) -> ProcessorPool {
    let net = MnistRfnn::analog(8, MeshBackend::Measured { base_seed: 7 }, 7);
    let bundle = ModelBundle::from_trained(&net).expect("analog net exports a bundle");
    let pool = ProcessorPool::new();
    if let Some((tile, fidelity)) = virt {
        pool.register(
            "virt8",
            Workload::Virtual {
                target: bundle.mesh.clone(),
                tile,
                fidelity,
                mnist: Some(bundle.clone()),
            },
            cfg,
        )
        .expect("register virt8 (is --tile one of 2/4/8?)");
    }
    pool.register("mnist8", Workload::Mnist { bundle, backend }, cfg).expect("register mnist8");
    pool.register("cls2x2", Workload::Classify2x2(demo_classifiers()), cfg)
        .expect("register cls2x2");
    let mesh8 = Workload::Processor(Box::new(DiscreteMesh::new(8, MeshBackend::Ideal)));
    pool.register("mesh8", mesh8, cfg).expect("register mesh8");
    pool
}

fn backend_from(args: &Args) -> Backend {
    if args.is_set("native") {
        Backend::Native
    } else {
        Backend::Pjrt(Manifest::default_dir())
    }
}

/// `--tile T [--fidelity F]` → the virtual-processor registration spec;
/// `Ok(None)` when --tile is absent or zero, `Err` (a usage message) for
/// tile sizes no processor is fabricated at or unknown fidelity names.
fn virt_from(args: &Args) -> Result<Option<(usize, Fidelity)>, String> {
    let tile = args.get_or("tile", 0usize);
    if tile == 0 {
        return Ok(None);
    }
    if !VALID_TILES.contains(&tile) {
        return Err(format!("--tile {tile} is not a physical tile size (have {VALID_TILES:?})"));
    }
    let fidelity = match args.get("fidelity") {
        None => Fidelity::Quantized,
        Some(name) => parse_fidelity(name).ok_or_else(|| {
            format!("unknown fidelity '{name}' (have: digital ideal quantized measured)")
        })?,
    };
    Ok(Some((tile, fidelity)))
}

fn cmd_serve(args: &Args) -> i32 {
    let requests = args.get_or("requests", 1000usize);
    let max_batch = args.get_or("batch", 256usize);
    let depth = args.get_or("depth", 1024usize);
    let cfg = PoolConfig {
        queue_depth: depth,
        batch: BatchPolicy { max_batch, max_wait: Duration::from_millis(2) },
        ..PoolConfig::default()
    };
    let virt = match virt_from(args) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if args.is_set("minimal") && args.get("listen").is_none() {
        eprintln!("--minimal requires --listen (a bare node has no local traffic to serve)");
        return 2;
    }
    let svc = if args.is_set("minimal") {
        // A bare cluster node: an empty pool, populated over the wire by
        // compile / shard_compile jobs (`rfnn cluster deploy`).
        Arc::new(ProcessorService::new(ProcessorPool::new()))
    } else {
        Arc::new(ProcessorService::new(default_pool(backend_from(args), cfg, virt)))
    };
    if let Some(addr) = args.get("listen") {
        // Network mode: the same pool behind the framed-TCP front end,
        // running until an `Admin::Shutdown` arrives over the wire.
        // `from_env` picks up RFNN_AUTH_TOKEN when set.
        let router = Arc::new(Router::new(svc.clone()));
        let fe = match TcpFrontEnd::bind(addr, router, TcpConfig::from_env()) {
            Ok(fe) => fe,
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        };
        println!("listening on {}", fe.local_addr());
        crate::obs::log::info("serve", "listening", &[("addr", fe.local_addr().to_string())]);
        fe.wait_shutdown();
        fe.shutdown();
        println!("{}", svc.metrics().report());
        println!("{}", svc.metrics().snapshot().to_string_pretty());
        return 0;
    }
    let (ds, _) = load_or_synthesize(requests.min(512), 1, 99);
    let images: Arc<Vec<Vec<f32>>> = Arc::new(
        ds.images.iter().map(|img| img.iter().map(|&v| v as f32).collect()).collect(),
    );
    let overloads = Arc::new(AtomicU64::new(0));
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    // Four closed-loop MNIST infer clients.
    let per_thread = requests / 4;
    for t in 0..4usize {
        let svc = svc.clone();
        let images = images.clone();
        let overloads = overloads.clone();
        handles.push(std::thread::spawn(move || {
            for k in 0..per_thread {
                let img = &images[(t * per_thread + k) % images.len()];
                loop {
                    match svc.submit(Job::Infer { processor: "mnist8".into(), image: img.clone() })
                    {
                        Ok(ticket) => {
                            let _ = ticket.wait();
                            break;
                        }
                        Err(SubmitError::Overloaded { .. }) => {
                            overloads.fetch_add(1, Ordering::Relaxed);
                            std::thread::yield_now();
                        }
                        Err(e) => {
                            eprintln!("infer submit: {e}");
                            return;
                        }
                    }
                }
            }
        }));
    }
    // One classify client across all six states.
    {
        let svc = svc.clone();
        let n = requests / 4;
        handles.push(std::thread::spawn(move || {
            for k in 0..n {
                let job = Job::Classify {
                    processor: "cls2x2".into(),
                    classifier: k % 6,
                    point: [k as f64 % 31.0, (3 * k) as f64 % 29.0],
                };
                if svc.submit_wait(job).is_err() {
                    return;
                }
            }
        }));
    }
    // One tiled-inference client when --tile registered virt8: the same
    // MNIST traffic served through the compiled tile fleet.
    if virt.is_some() {
        let svc = svc.clone();
        let images = images.clone();
        let n = (requests / 8).max(1);
        handles.push(std::thread::spawn(move || {
            if images.is_empty() {
                return; // --requests 0: nothing to send
            }
            for k in 0..n {
                let img = images[k % images.len()].clone();
                if svc.submit_wait(Job::Infer { processor: "virt8".into(), image: img }).is_err() {
                    return;
                }
            }
        }));
    }
    // One raw-apply + reprogram client against the bare mesh.
    {
        let svc = svc.clone();
        let n = (requests / 64).max(2);
        handles.push(std::thread::spawn(move || {
            use crate::math::c64::C64;
            use crate::math::cmat::CMat;
            let x = CMat::from_fn(8, 16, |i, j| {
                C64::new(0.05 * i as f64 - 0.2 + 0.01 * j as f64, 0.02 * i as f64)
            });
            for k in 0..n {
                let _ = svc.submit_wait(Job::RawApply { processor: "mesh8".into(), x: x.clone() });
                if k % 8 == 7 {
                    // 8×8 Reck mesh: 28 cells, 56 state variables.
                    let code: Vec<usize> = (0..56).map(|i| (i + k) % 6).collect();
                    let _ =
                        svc.submit_wait(Job::Reprogram { processor: "mesh8".into(), code });
                }
            }
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    let dt = t0.elapsed();
    println!(
        "{} infer requests in {:.2?} → {:.0} req/s ({} overload sheds)",
        per_thread * 4,
        dt,
        (per_thread * 4) as f64 / dt.as_secs_f64(),
        overloads.load(Ordering::Relaxed)
    );
    println!("{}", svc.metrics().report());
    for info in svc.pool().processors() {
        println!(
            "  {}@v{} {:?} {}×{} queue≤{} kinds {:?}",
            info.name,
            info.version,
            info.fidelity,
            info.dims.0,
            info.dims.1,
            info.capacity,
            info.kinds.iter().map(|k| k.name()).collect::<Vec<_>>()
        );
    }
    println!("{}", svc.metrics().snapshot().to_string_pretty());
    0
}

fn cmd_job(args: &Args) -> i32 {
    let Some(text) = args.positional.first() else {
        eprintln!("usage: rfnn job '<wire json>' (see WIRE_VERSION in coordinator::service)");
        return 2;
    };
    // Fail fast on malformed documents BEFORE building the pool (usage
    // error, exit 2); the router re-decodes on the shared dispatch path.
    if let Err(e) = Job::decode(text) {
        eprintln!("bad job: {e}");
        return 2;
    }
    let virt = match virt_from(args) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let svc = ProcessorService::new(default_pool(backend_from(args), PoolConfig::default(), virt));
    let router = Router::new(Arc::new(svc));
    // The same Endpoint path the TCP front end drives: decode + validate
    // + submit under one roof, wait by ticket id.
    match router.submit_wire(text.as_bytes()) {
        Ok(id) => match router.wait(id) {
            Ok(result) => {
                println!("{}", result.to_json().to_string_pretty());
                i32::from(matches!(result, JobResult::Rejected { .. }))
            }
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        },
        Err(e @ RouterError::Decode(_)) => {
            eprintln!("bad job: {e}");
            2
        }
        Err(e) => {
            eprintln!("rejected: {e}");
            1
        }
    }
}

/// `rfnn client`: drive a remote `rfnn serve --listen` host over the
/// framed-TCP transport — jobs and the admin plane, one wire schema.
fn cmd_client(args: &Args) -> i32 {
    let addr = args.get("connect").unwrap_or("127.0.0.1:7878");
    let usage = || {
        eprintln!(
            "usage: rfnn client [--connect ADDR] job '<wire json>'\n\
             \x20      rfnn client [--connect ADDR] admin \
             <health|metrics|processors|cluster|trace|shutdown>\n\
             \x20      rfnn client admin metrics --format prom   # Prometheus text exposition\n\
             \x20      rfnn client admin trace [--n N]           # last N completed traces"
        );
        2
    };
    let Some(verb) = args.positional.first() else {
        return usage();
    };
    match verb.as_str() {
        "job" => {
            let Some(text) = args.positional.get(1) else {
                return usage();
            };
            let job = match Job::decode(text) {
                Ok(j) => j,
                Err(e) => {
                    eprintln!("bad job: {e}");
                    return 2;
                }
            };
            let client = match RemoteClient::connect(addr) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            };
            match client.submit_wait(job) {
                Ok(result) => {
                    println!("{}", result.to_json().to_string_pretty());
                    i32::from(matches!(result, JobResult::Rejected { .. }))
                }
                Err(e) => {
                    eprintln!("error: {e}");
                    1
                }
            }
        }
        "admin" => {
            let admin = match args.positional.get(1).map(String::as_str) {
                Some("health") => Admin::Health,
                // `--format prom` selects the Prometheus text exposition
                // of the same snapshot (scrape-ready; raw text, not JSON).
                Some("metrics") | Some("metrics_snapshot") => {
                    match args.get("format") {
                        Some("prom") | Some("prometheus") => Admin::MetricsText,
                        Some(other) => {
                            eprintln!("unknown metrics format '{other}' (have: prom)");
                            return 2;
                        }
                        None => Admin::MetricsSnapshot,
                    }
                }
                Some("processors") | Some("list_processors") => Admin::ListProcessors,
                Some("cluster") | Some("cluster_health") => Admin::ClusterHealth,
                Some("trace") | Some("trace_dump") => {
                    Admin::TraceDump { n: args.get_or("n", TRACE_DUMP_DEFAULT) }
                }
                Some("shutdown") => Admin::Shutdown,
                _ => return usage(),
            };
            let client = match RemoteClient::connect(addr) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            };
            match client.admin(admin) {
                // The Prometheus exposition is already line-oriented text.
                Ok(AdminReply::MetricsText(text)) => {
                    print!("{text}");
                    0
                }
                Ok(reply) => {
                    println!("{}", reply.to_json().to_string_pretty());
                    0
                }
                Err(e) => {
                    eprintln!("error: {e}");
                    1
                }
            }
        }
        _ => usage(),
    }
}

/// The cluster commands' shared target derivation: every process (plan,
/// deploy, serve, and any node recompiling locally to cross-check)
/// reconstructs the SAME seeded random weight matrix from
/// `(--rows, --cols, --seed)`, so no weights travel out of band.
fn cluster_spec_from(args: &Args) -> Result<(CMat, PlanSpec, usize, u64), String> {
    let rows = args.get_or("rows", 8usize);
    let cols = args.get_or("cols", rows);
    let tile = args.get_or("tile", 2usize);
    if !VALID_TILES.contains(&tile) {
        return Err(format!("--tile {tile} is not a physical tile size (have {VALID_TILES:?})"));
    }
    let fid_name = args.get("fidelity").unwrap_or("measured");
    let fidelity = parse_fidelity(fid_name).ok_or_else(|| {
        format!("unknown fidelity '{fid_name}' (have: digital ideal quantized measured)")
    })?;
    let cal_name = args.get("calibration").unwrap_or("measured");
    let calibration = Calibration::from_name(cal_name)
        .ok_or_else(|| format!("unknown calibration rule '{cal_name}' (have: measured ideal)"))?;
    let seed = args.get_or("seed", 2023u64);
    let mut spec = PlanSpec::new(tile, fidelity).with_calibration(calibration);
    if let Some(v) = args.get("fab-seed") {
        let fab = v
            .parse::<u64>()
            .map_err(|_| format!("--fab-seed '{v}' is not an unsigned 64-bit integer"))?;
        spec = spec.with_seed(fab);
    }
    let mut rng = Rng::new(seed);
    let target = CMat::from_fn(rows, cols, |_, _| C64::real(rng.normal()));
    let n = args.get_or("shards", 2usize);
    Ok((target, spec, n, seed))
}

/// `rfnn cluster plan|deploy|serve`: shard a seeded random target across
/// remote nodes (see the USAGE text for the full story).
fn cmd_cluster(args: &Args) -> i32 {
    let usage = || {
        eprintln!(
            "usage: rfnn cluster plan   [--rows M --cols N --tile T --fidelity F --seed S \
             --shards N]\n\
             \x20      rfnn cluster deploy --nodes A,B,C [--replicas R --name NAME …plan \
             flags]\n\
             \x20      rfnn cluster serve  --nodes A,B,C [--replicas R --requests N --batch B \
             …plan flags]"
        );
        2
    };
    let Some(verb) = args.positional.first().map(String::as_str) else {
        return usage();
    };
    if !matches!(verb, "plan" | "deploy" | "serve") {
        return usage();
    }
    let (target, spec, n, seed) = match cluster_spec_from(args) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let shards = match plan_shards(&target, &spec, n) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("plan failed: {e}");
            return 2;
        }
    };
    println!(
        "{} shard(s) over a {}×{} target on {}×{} tiles ({:?}, target seed {seed})",
        shards.len(),
        target.rows(),
        target.cols(),
        spec.tile,
        spec.tile,
        spec.fidelity,
    );
    for (i, s) in shards.iter().enumerate() {
        println!(
            "  s{i}: tile-rows {}..{} → output rows {}..{} ({}×{} slice)",
            s.row_start,
            s.row_start + s.grid_rows,
            s.out_row_start(),
            s.out_row_start() + s.out_rows(),
            s.out_rows(),
            s.cols,
        );
    }
    if verb == "plan" {
        return 0;
    }
    let Some(node_list) = args.get("nodes") else {
        eprintln!("cluster {verb} needs --nodes A,B,C (addresses of `rfnn serve --listen` hosts)");
        return 2;
    };
    let nodes: Vec<String> =
        node_list.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
    if nodes.is_empty() {
        eprintln!("--nodes lists no addresses");
        return 2;
    }
    let replicas = args.get_or("replicas", 1usize).max(1);
    let name = args.get("name").unwrap_or("net");
    // Round-robin placement: shard s, replica r → nodes[(s·R + r) % len].
    // With R ≥ 2 and ≥ 2 nodes, a shard's replicas land on distinct nodes
    // whenever enough nodes exist.
    let addrs: Vec<Vec<String>> = (0..shards.len())
        .map(|s| (0..replicas).map(|r| nodes[(s * replicas + r) % nodes.len()].clone()).collect())
        .collect();
    let sp = match ShardedProcessor::deploy(name, &shards, &addrs, ShardConfig::default()) {
        Ok(sp) => sp,
        Err(e) => {
            eprintln!("deploy failed: {e}");
            return 1;
        }
    };
    for (i, list) in addrs.iter().enumerate() {
        println!("  {name}.s{i} ← {}", list.join(", "));
    }
    println!(
        "deployed '{name}': {} shard(s) × {replicas} replica(s), cluster {}",
        shards.len(),
        sp.cluster_metrics().worst_health().name()
    );
    if verb == "deploy" {
        return 0;
    }
    // serve: drive random batches through the scatter/gather coordinator
    // and hold every answer to the single-process compile, bit-for-bit.
    let full = match VirtualProcessor::compile(&target, &spec) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("local reference compile failed: {e}");
            return 1;
        }
    };
    let requests = args.get_or("requests", 16usize);
    let batch = args.get_or("batch", 8usize).max(1);
    let mut rng = Rng::new(seed ^ 0xC1A57E12);
    let t0 = std::time::Instant::now();
    for k in 0..requests {
        let x = CMat::from_fn(target.cols(), batch, |_, _| C64::new(rng.normal(), rng.normal()));
        let y = match sp.try_apply_batch(&x) {
            Ok(y) => y,
            Err(e) => {
                eprintln!("batch {k}: {e}");
                return 1;
            }
        };
        if y != LinearProcessor::apply_batch(&full, &x) {
            eprintln!("batch {k}: sharded output differs from the single-process compile");
            return 1;
        }
    }
    let dt = t0.elapsed();
    println!(
        "{requests} batch(es) × {batch} column(s) in {dt:.2?} — sharded ≡ single-process, \
         bit-identical"
    );
    println!("{}", sp.cluster_metrics().snapshot().to_string_pretty());
    0
}

/// `rfnn compile`: lower a seeded random M×N weight matrix onto a fleet
/// of T×T tiles and print the plan summary, then recompile to show the
/// plan-cache hit. At measured fidelity the report compares
/// calibration-aware lowering against nearest-ideal snapping, and
/// `--train EVALS` runs in-situ fleet DSPSA against the same target.
fn cmd_compile(args: &Args) -> i32 {
    let rows = args.get_or("rows", 8usize);
    let cols = args.get_or("cols", rows);
    let tile = args.get_or("tile", 4usize);
    let seed = args.get_or("seed", 2023u64);
    let fid_name = args.get("fidelity").unwrap_or("quantized");
    let Some(fidelity) = parse_fidelity(fid_name) else {
        eprintln!("unknown fidelity '{fid_name}' (have: digital ideal quantized measured)");
        return 2;
    };
    let cal_name = args.get("calibration").unwrap_or("measured");
    let Some(calibration) = Calibration::from_name(cal_name) else {
        eprintln!("unknown calibration rule '{cal_name}' (have: measured ideal)");
        return 2;
    };
    let train_evals = args.get_or("train", 0usize);
    let mode_name = args.get("dspsa-mode").unwrap_or("block");
    let Some(mode) = PerturbMode::from_name(mode_name) else {
        eprintln!("unknown DSPSA mode '{mode_name}' (have: monolithic block block-random)");
        return 2;
    };
    let mut rng = Rng::new(seed);
    let target = CMat::from_fn(rows, cols, |_, _| C64::real(rng.normal()));
    let mut spec = PlanSpec::new(tile, fidelity).with_calibration(calibration);
    if let Some(v) = args.get("fab-seed") {
        match v.parse::<u64>() {
            Ok(fab) => spec = spec.with_seed(fab),
            Err(_) => {
                eprintln!("--fab-seed '{v}' is not an unsigned 64-bit integer");
                return 2;
            }
        }
    }
    let compiler = Compiler::global();
    let plan = match compiler.compile(&target, &spec) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("compile failed: {e} (valid tiles: {VALID_TILES:?})");
            return 2;
        }
    };
    println!("{}", plan.summary());
    let rel = plan.fro_error / target.fro_norm().max(1e-300);
    println!("relative error ‖assembled − target‖_F / ‖target‖_F = {rel:.3e}");
    if fidelity == Fidelity::Measured {
        // Lower under the other selection rule and report the gap the
        // calibration tables buy (or cost, with --calibration ideal).
        let twin_rule = match calibration {
            Calibration::NearestMeasured => Calibration::NearestIdeal,
            Calibration::NearestIdeal => Calibration::NearestMeasured,
        };
        let twin = compiler
            .compile(&target, &spec.with_calibration(twin_rule))
            .expect("same target recompiles under the twin rule");
        let (cal_err, snap_err) = match calibration {
            Calibration::NearestMeasured => (plan.fro_error, twin.fro_error),
            Calibration::NearestIdeal => (twin.fro_error, plan.fro_error),
        };
        println!(
            "calibration: nearest-measured fro_error {cal_err:.4e} vs nearest-ideal \
             {snap_err:.4e} ({:.1}% tighter)",
            100.0 * (snap_err - cal_err) / snap_err.max(1e-300)
        );
    }
    // Second compilation of the same weights: recipes come from the cache.
    let again = compiler.compile(&target, &spec).expect("same spec recompiles");
    println!(
        "recompile: cache {} ({} hit(s), {} miss(es), {} plan(s) resident, {} calibration \
         table(s))",
        if again.cache_hit { "HIT — synthesis skipped" } else { "MISS" },
        compiler.cache().hits(),
        compiler.cache().misses(),
        compiler.cache().len(),
        compiler.calibrations().len(),
    );
    if train_evals > 0 {
        let mut vp = VirtualProcessor::new(plan);
        match vp.train_states(
            &target,
            mode,
            train_evals,
            crate::nn::dspsa::DspsaConfig::default(),
            args.get_or("dspsa-seed", 0xD5_05Au64),
        ) {
            Some(r) => {
                println!(
                    "in-situ DSPSA ({}): {} evals, loss {:.4e} → {:.4e} ({:.1}% better)",
                    r.mode.name(),
                    r.evals,
                    r.initial_loss,
                    r.final_loss,
                    r.improvement_pct()
                );
                // A few evenly spaced best-so-far waypoints.
                let n = r.trace.len();
                let pts = n.min(5);
                for k in 1..=pts {
                    let at = n * k / pts - 1;
                    println!("  step {:>4}: best {:.4e}", at + 1, r.trace[at]);
                }
            }
            None => println!(
                "--train: no programmable states at {fidelity:?} fidelity (use quantized or \
                 measured)"
            ),
        }
    }
    0
}

/// `rfnn lint` — run the in-repo static analysis pass (see
/// [`crate::analysis`]) over the tree rooted at `--root` (default the
/// current directory). Exit code 0 when clean, 1 with `path:line`
/// diagnostics when violations are found, 2 on usage errors.
fn cmd_lint(args: &Args) -> i32 {
    let format = args.get("format").unwrap_or("text");
    if !matches!(format, "text" | "json") {
        eprintln!("unknown --format '{format}' (have: text json)");
        return 2;
    }
    let rule = args.get("rule");
    if let Some(r) = rule {
        if crate::analysis::rules::find(r).is_none() {
            eprintln!("unknown --rule '{r}' (have: {})", crate::analysis::rule_ids().join(" "));
            return 2;
        }
    }
    let root = std::path::PathBuf::from(args.get("root").unwrap_or("."));
    match crate::analysis::lint_tree(&root, rule) {
        Ok(report) => {
            match format {
                "json" => println!("{}", report.to_json()),
                _ => print!("{}", report.to_text()),
            }
            if report.is_clean() { 0 } else { 1 }
        }
        Err(e) => {
            eprintln!("lint failed: {e}");
            2
        }
    }
}

fn cmd_info() -> i32 {
    println!("rfnn {} — paper doi:10.1109/TMTT.2023.3293054", env!("CARGO_PKG_VERSION"));
    println!("{}", crate::math::gemm::kernel_report());
    let dir = Manifest::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {:?} (N={}, C={}, batches {:?})", dir, m.n, m.cols, m.batch_sizes);
            for name in m.artifacts.keys() {
                println!("  {name}");
            }
        }
        Err(e) => println!("artifacts: unavailable — {e}"),
    }
    match crate::runtime::Engine::cpu(&dir) {
        Ok(engine) => println!("PJRT platform: {}", engine.platform()),
        Err(e) => println!("PJRT: unavailable — {e}"),
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn command_and_positionals() {
        let a = parse("bench fig12 extra");
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["fig12", "extra"]);
    }

    #[test]
    fn flags_in_all_styles() {
        let a = parse("serve --requests 100 --batch=32 --quick");
        assert_eq!(a.get_or("requests", 0usize), 100);
        assert_eq!(a.get_or("batch", 0usize), 32);
        assert!(a.is_set("quick"));
        assert!(!a.is_set("absent"));
    }

    #[test]
    fn flag_value_not_stolen_by_next_flag() {
        let a = parse("cmd --a --b 7");
        assert_eq!(a.get("a"), Some("true"));
        assert_eq!(a.get_or("b", 0u32), 7);
    }

    #[test]
    fn defaults_apply_on_parse_failure() {
        let a = parse("cmd --n notanumber");
        assert_eq!(a.get_or("n", 42u32), 42);
    }

    #[test]
    fn unknown_command_prints_usage_and_succeeds() {
        assert_eq!(run(&parse("")), 0);
        assert_eq!(run(&parse("definitely-not-a-command")), 0);
    }

    #[test]
    fn compile_command_prints_plans_and_rejects_bad_specs() {
        // Ragged target, quantized fleet.
        assert_eq!(run(&parse("compile --rows 5 --cols 3 --tile 2 --fidelity quantized")), 0);
        // Digital default-size plan on 4×4 tiles.
        assert_eq!(run(&parse("compile --fidelity digital")), 0);
        // Invalid tile size and fidelity exit with a usage error.
        assert_eq!(run(&parse("compile --tile 3")), 2);
        assert_eq!(run(&parse("compile --fidelity bogus")), 2);
    }

    #[test]
    fn compile_command_calibration_and_training_flags() {
        // Measured fidelity prints the calibrated-vs-ideal comparison in
        // both directions of --calibration.
        assert_eq!(run(&parse("compile --rows 4 --cols 4 --tile 2 --fidelity measured")), 0);
        assert_eq!(
            run(&parse(
                "compile --rows 4 --cols 4 --tile 2 --fidelity measured --calibration ideal \
                 --fab-seed 7"
            )),
            0
        );
        // In-situ DSPSA on a quantized fleet, block and monolithic.
        assert_eq!(
            run(&parse("compile --rows 4 --cols 4 --tile 2 --fidelity quantized --train 20")),
            0
        );
        assert_eq!(
            run(&parse(
                "compile --rows 4 --cols 4 --tile 2 --fidelity quantized --train 10 \
                 --dspsa-mode monolithic"
            )),
            0
        );
        // --train on a stateless fleet reports, not panics.
        assert_eq!(run(&parse("compile --tile 2 --fidelity digital --train 10")), 0);
        // Bad calibration, DSPSA-mode and fab-seed spellings are usage
        // errors, not silent defaults.
        assert_eq!(run(&parse("compile --calibration bogus")), 2);
        assert_eq!(run(&parse("compile --train 4 --dspsa-mode bogus")), 2);
        assert_eq!(run(&parse("compile --fab-seed 0xBEEF")), 2);
    }

    #[test]
    fn fidelity_names_parse() {
        assert_eq!(parse_fidelity("digital"), Some(Fidelity::Digital));
        assert_eq!(parse_fidelity("i"), Some(Fidelity::Ideal));
        assert_eq!(parse_fidelity("quantized"), Some(Fidelity::Quantized));
        assert_eq!(parse_fidelity("m"), Some(Fidelity::Measured));
        assert_eq!(parse_fidelity("analog"), None);
    }

    #[test]
    fn virt_flag_defaults_and_validation() {
        assert_eq!(virt_from(&parse("serve")), Ok(None));
        assert_eq!(virt_from(&parse("serve --tile 4")), Ok(Some((4, Fidelity::Quantized))));
        assert_eq!(
            virt_from(&parse("serve --tile 2 --fidelity digital")),
            Ok(Some((2, Fidelity::Digital)))
        );
        // Bad tile sizes and fidelity typos are usage errors, not panics
        // (serve/job print the message and exit 2).
        assert!(virt_from(&parse("serve --tile 3")).is_err());
        assert!(virt_from(&parse("serve --tile 4 --fidelity measurd")).is_err());
    }

    #[test]
    fn invalid_kernel_is_a_usage_error_before_dispatch() {
        // The invalid spelling must exit 2 WITHOUT touching the process
        // environment (tests run in parallel; set_var is only reached on
        // the validated path, which this test deliberately avoids).
        assert_eq!(run(&parse("info --kernel neon")), 2);
        assert_eq!(run(&parse("bench perf --kernel fast")), 2);
    }

    #[test]
    fn bench_rejects_invalid_tile_before_running() {
        assert_eq!(run(&parse("bench perf --tile 3")), 2);
        assert_eq!(run(&parse("bench perf --tile nope")), 2);
    }

    #[test]
    fn client_command_usage_and_decode_errors() {
        // Usage errors and malformed job documents exit 2 without ever
        // opening a socket.
        assert_eq!(run(&parse("client")), 2);
        assert_eq!(run(&parse("client bogus")), 2);
        assert_eq!(run(&parse("client job")), 2);
        assert_eq!(run(&parse("client admin")), 2);
        assert_eq!(run(&parse("client admin nope")), 2);
        assert_eq!(run(&parse("client job {not-json}")), 2);
    }

    #[test]
    fn cluster_command_usage_and_plan() {
        assert_eq!(run(&parse("cluster")), 2);
        assert_eq!(run(&parse("cluster bogus")), 2);
        // A pure planning pass opens no sockets.
        assert_eq!(
            run(&parse("cluster plan --rows 6 --cols 4 --tile 2 --shards 3 --fidelity quantized")),
            0
        );
        // Too many shards for the grid, and bad spellings, are usage
        // errors caught before any connection is dialed.
        assert_eq!(run(&parse("cluster plan --rows 4 --tile 2 --shards 9")), 2);
        assert_eq!(run(&parse("cluster plan --tile 3")), 2);
        assert_eq!(run(&parse("cluster plan --fidelity bogus")), 2);
        assert_eq!(run(&parse("cluster plan --calibration bogus")), 2);
        assert_eq!(run(&parse("cluster plan --fab-seed 0xBEEF")), 2);
        // deploy/serve without usable --nodes never dial anything.
        assert_eq!(run(&parse("cluster deploy")), 2);
        assert_eq!(run(&parse("cluster serve --nodes ,")), 2);
    }

    #[test]
    fn serve_minimal_requires_listen() {
        assert_eq!(run(&parse("serve --minimal")), 2);
    }

    #[test]
    fn job_command_rejects_malformed_wire_input() {
        // No positional → usage error; bad JSON → decode error. Neither
        // should build a pool or panic.
        assert_eq!(run(&parse("job")), 2);
        assert_eq!(run(&parse("job {not-json}")), 2);
        let wrong_version = r#"{"v":999,"kind":"infer","processor":"mnist8","image":[]}"#;
        let a = Args::parse(["job".to_string(), wrong_version.to_string()]);
        assert_eq!(run(&a), 2);
    }

    #[test]
    fn lint_usage_errors_before_any_tree_walk() {
        assert_eq!(run(&parse("lint --format xml")), 2);
        assert_eq!(run(&parse("lint --rule not-a-rule")), 2);
        // A root that is not a crate checkout is an I/O error, not a panic.
        assert_eq!(run(&parse("lint --root /definitely/not/here")), 2);
    }

    #[test]
    fn lint_self_check_through_the_cli_is_clean() {
        // The library-level self check lives in `analysis::tests`; this one
        // exercises the full `rfnn lint` surface (flag parsing, tree walk,
        // report printing, exit code) against the repo's own tree.
        let a = parse(&format!("lint --root {}", env!("CARGO_MANIFEST_DIR")));
        assert_eq!(run(&a), 0);
    }
}
