//! Hand-rolled CLI argument parsing (the offline vendor set has no clap).
//!
//! Grammar: `rfnn <command> [--flag[=value] | --flag value | positional]…`

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (the subcommand).
    pub command: Option<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` / bare `--key` (value "true").
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Flag as string.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// Flag parsed to any `FromStr`, with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Boolean flag (present and not "false").
    pub fn is_set(&self, key: &str) -> bool {
        self.get(key).map(|v| v != "false").unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn command_and_positionals() {
        let a = parse("bench fig12 extra");
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["fig12", "extra"]);
    }

    #[test]
    fn flags_in_all_styles() {
        let a = parse("serve --requests 100 --batch=32 --quick");
        assert_eq!(a.get_or("requests", 0usize), 100);
        assert_eq!(a.get_or("batch", 0usize), 32);
        assert!(a.is_set("quick"));
        assert!(!a.is_set("absent"));
    }

    #[test]
    fn flag_value_not_stolen_by_next_flag() {
        let a = parse("cmd --a --b 7");
        assert_eq!(a.get("a"), Some("true"));
        assert_eq!(a.get_or("b", 0u32), 7);
    }

    #[test]
    fn defaults_apply_on_parse_failure() {
        let a = parse("cmd --n notanumber");
        assert_eq!(a.get_or("n", 42u32), 42);
    }
}
