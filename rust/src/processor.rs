//! The unified execution abstraction for every linear backend.
//!
//! Everything in this system that multiplies a vector by a matrix — the
//! ideal analytic mesh, the measured (virtual-VNA) [`DiscreteMesh`], a
//! Table-I-quantized mesh, or a plain digital [`CMat`] — is a *linear
//! processor*: it owns an `out × in` transfer matrix and executes
//! matrix–matrix products against batches of input vectors. The
//! [`LinearProcessor`] trait is the single interface the NN layers and the
//! serving coordinator program against, so swapping fidelity levels (or,
//! later, sharding across several physical processors) never touches the
//! forward-path code.
//!
//! The hot path is [`LinearProcessor::apply_batch`]: one blocked complex
//! GEMM ([`CMat::gemm`]) over the whole batch instead of a per-vector
//! `matvec` loop. Batches are laid out column-wise (`x` has shape
//! `in × B`, one vector per column), matching the math convention
//! `Y = M·X`; `apply` is the `B = 1` special case.

use crate::math::c64::C64;
use crate::math::cmat::CMat;
use crate::mesh::propagate::DiscreteMesh;
use crate::util::error::Result;

/// How faithfully a backend models the physical processor.
///
/// Totally ordered/hashable so fidelity can key compiled-plan caches
/// (`crate::compiler::cache`); the derived order is declaration order and
/// carries no "better than" meaning.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Fidelity {
    /// Exact digital arithmetic (reference backend; not a device model).
    Digital,
    /// Ideal analytic unit cells at the discrete Table-I phases (eq. 5).
    Ideal,
    /// A mesh programmed by quantizing a continuous target onto the 36
    /// discrete states (Table I) — the paper's main precision limit.
    Quantized,
    /// Per-cell measured (virtual-VNA) transfer blocks with fabrication
    /// imperfections and noise — the stand-in for real hardware.
    Measured,
}

impl Fidelity {
    /// Stable wire/CLI name (lowercase; round-trips through
    /// [`Self::from_name`]).
    pub fn name(self) -> &'static str {
        match self {
            Fidelity::Digital => "digital",
            Fidelity::Ideal => "ideal",
            Fidelity::Quantized => "quantized",
            Fidelity::Measured => "measured",
        }
    }

    /// Parse a fidelity name (full word or first letter), as used by the
    /// CLI `--fidelity` flag and the `Job::Compile` wire form.
    pub fn from_name(name: &str) -> Option<Fidelity> {
        match name {
            "digital" | "d" => Some(Fidelity::Digital),
            "ideal" | "i" => Some(Fidelity::Ideal),
            "quantized" | "q" => Some(Fidelity::Quantized),
            "measured" | "m" => Some(Fidelity::Measured),
            _ => None,
        }
    }
}

/// Cost metadata for reprogramming a processor to new weights/states.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReprogramCost {
    /// Number of discrete programmable state variables (0 = weights are
    /// fixed or directly writable, as for the digital reference).
    pub state_vars: usize,
    /// Approximate FLOPs to rebuild the composed transfer matrix after a
    /// state write (the DSPSA inner-loop cost).
    pub recompose_flops: u64,
}

impl ReprogramCost {
    /// A backend with directly writable weights and no recompose step.
    pub const FREE: ReprogramCost = ReprogramCost { state_vars: 0, recompose_flops: 0 };
}

/// A linear backend: an `out × in` transfer matrix plus batched execution.
///
/// Implementations only *must* provide the metadata and [`Self::matrix`];
/// `apply_batch`/`apply` default to the blocked GEMM over the composed
/// matrix, which is the right answer for every backend that caches its
/// composition (all current ones do).
///
/// `Send + Sync` is part of the contract: workers move processors across
/// threads and the tiled executor fans `&self` applies across a scoped
/// worker pool. Every backend is plain data (matrices, state vectors,
/// `OnceLock` caches), so the bounds are free.
pub trait LinearProcessor: Send + Sync {
    /// `(out_dim, in_dim)` of the transfer matrix.
    fn dims(&self) -> (usize, usize);

    /// Modelling fidelity of this backend.
    fn fidelity(&self) -> Fidelity;

    /// Cost of reprogramming this backend to a new state.
    fn reprogram_cost(&self) -> ReprogramCost;

    /// The composed transfer matrix.
    fn matrix(&self) -> &CMat;

    /// Execute a whole batch: `Y = M·X` with `x` of shape `in × B` (one
    /// input vector per column). Returns `out × B`.
    fn apply_batch(&self, x: &CMat) -> CMat {
        let (out, inp) = self.dims();
        assert_eq!(x.rows(), inp, "apply_batch: {out}x{inp} processor, {} input rows", x.rows());
        self.matrix().gemm(x)
    }

    /// Fallible [`Self::apply_batch`] for backends whose execution can
    /// fail at runtime — a sharded processor whose remote nodes are
    /// unreachable, for example. The serving layer drives this entry so a
    /// backend failure becomes a rejected job instead of a dead worker;
    /// local backends use the default, which cannot fail (shape mismatches
    /// are caller bugs and still panic).
    fn try_apply_batch(&self, x: &CMat) -> Result<CMat> {
        Ok(self.apply_batch(x))
    }

    /// [`Self::apply_batch`] into a caller-owned output buffer (reshaped
    /// in place, fully overwritten) — the allocation-free entry the tiled
    /// executor's arena drives: in steady state `out` is a reused slot
    /// and the dispatch performs no heap allocation. Must produce results
    /// bit-identical to [`Self::apply_batch`].
    fn apply_batch_into(&self, x: &CMat, out: &mut CMat) {
        let (o, inp) = self.dims();
        assert_eq!(x.rows(), inp, "apply_batch: {o}x{inp} processor, {} input rows", x.rows());
        self.matrix().gemm_into(x, out);
    }

    /// Execute one vector — the batch-1 special case of [`Self::apply_batch`].
    fn apply(&self, x: &[C64]) -> Vec<C64> {
        self.matrix().matvec(x)
    }

    /// Discrete device states as a flat code (θ0, φ0, θ1, φ1, …), if this
    /// backend is state-programmed. `None` for fixed-weight backends.
    fn state_code(&self) -> Option<Vec<usize>> {
        None
    }

    /// Program the backend from a flat state code; returns `false` if the
    /// backend has no programmable states.
    fn set_state_code(&mut self, _code: &[usize]) -> bool {
        false
    }

    /// Escape hatch for hardware-ABI export (AOT coefficient planes,
    /// failure injection): the underlying mesh, when there is one.
    fn as_mesh(&self) -> Option<&DiscreteMesh> {
        None
    }

    /// Mutable counterpart of [`Self::as_mesh`]. Backends that cache a
    /// derived composition (e.g. a quantized mesh with an input phase
    /// layer) return `None` to protect cache coherence.
    fn as_mesh_mut(&mut self) -> Option<&mut DiscreteMesh> {
        None
    }
}

/// The digital reference backend: a plain dense complex matrix.
impl LinearProcessor for CMat {
    fn dims(&self) -> (usize, usize) {
        (self.rows(), self.cols())
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::Digital
    }

    fn reprogram_cost(&self) -> ReprogramCost {
        ReprogramCost::FREE
    }

    fn matrix(&self) -> &CMat {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Rng;

    #[test]
    fn cmat_is_the_digital_reference() {
        let mut rng = Rng::new(1);
        let m = CMat::from_fn(3, 5, |_, _| C64::new(rng.normal(), rng.normal()));
        let p: &dyn LinearProcessor = &m;
        assert_eq!(p.dims(), (3, 5));
        assert_eq!(p.fidelity(), Fidelity::Digital);
        assert_eq!(p.reprogram_cost(), ReprogramCost::FREE);
        assert!(p.state_code().is_none());
        assert!(p.as_mesh().is_none());
    }

    #[test]
    fn apply_batch_matches_columnwise_apply() {
        let mut rng = Rng::new(2);
        let m = CMat::from_fn(4, 4, |_, _| C64::new(rng.normal(), rng.normal()));
        let x = CMat::from_fn(4, 7, |_, _| C64::new(rng.normal(), rng.normal()));
        let y = LinearProcessor::apply_batch(&m, &x);
        assert_eq!((y.rows(), y.cols()), (4, 7));
        for j in 0..7 {
            let col = x.col(j);
            let want = LinearProcessor::apply(&m, &col);
            for i in 0..4 {
                assert!((y[(i, j)] - want[i]).abs() < 1e-13);
            }
        }
    }

    #[test]
    fn apply_batch_into_is_bit_identical_and_reusable() {
        let mut rng = Rng::new(3);
        let m = CMat::from_fn(5, 3, |_, _| C64::new(rng.normal(), rng.normal()));
        let mut out = CMat::zeros(0, 0);
        for &b in &[7usize, 1, 7] {
            let x = CMat::from_fn(3, b, |_, _| C64::new(rng.normal(), rng.normal()));
            LinearProcessor::apply_batch_into(&m, &x, &mut out);
            assert_eq!(out, LinearProcessor::apply_batch(&m, &x), "batch {b}");
        }
    }

    #[test]
    #[should_panic(expected = "apply_batch")]
    fn apply_batch_rejects_wrong_input_rows() {
        let m = CMat::eye(3);
        let x = CMat::zeros(4, 2);
        let _ = LinearProcessor::apply_batch(&m, &x);
    }

    #[test]
    fn fidelity_names_round_trip() {
        for f in [Fidelity::Digital, Fidelity::Ideal, Fidelity::Quantized, Fidelity::Measured] {
            assert_eq!(Fidelity::from_name(f.name()), Some(f));
            assert_eq!(Fidelity::from_name(&f.name()[..1]), Some(f));
        }
        assert_eq!(Fidelity::from_name("analog"), None);
    }
}
