//! The paper's 2×2 reconfigurable linear RF analog processor (unit cell).
//!
//! Three fidelity levels, matching the paper's "theory / simulation /
//! measurement" triptych (Fig. 6):
//!
//! * [`ideal`] — closed-form eq. (5): `t(θ, φ) = j·e^{-jθ/2} ·
//!   [[e^{-jφ}·sin(θ/2), e^{-jφ}·cos(θ/2)], [cos(θ/2), −sin(θ/2)]]`.
//! * [`circuit`] — physical branch-line hybrids + switched-line phase
//!   shifters on RO4360G2, assembled with the netlist reducer; produces the
//!   frequency responses of Fig. 5 ("simulation").
//! * [`vna`] — the circuit model with seeded fabrication perturbations and
//!   measurement noise — the stand-in for the paper's measured prototype
//!   ("measurement"). See DESIGN.md §2 for the substitution argument.
//! * [`testbench`] — power-domain excitation/detection used by the RFNN
//!   experiments (Figs. 10–12): feed voltage magnitudes into P1/P4, read
//!   detected power at P2/P3.

pub mod activation;
pub mod circuit;
pub mod ideal;
pub mod testbench;
pub mod vna;

/// A device state: which of the six paths each phase shifter selects.
/// `L_nL_m` in the paper's notation is `State { theta: n-1, phi: m-1 }`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct State {
    /// θ phase-shifter path index, 0..6 (paper's L1..L6).
    pub theta: usize,
    /// φ phase-shifter path index, 0..6.
    pub phi: usize,
}

impl State {
    /// All 36 states in row-major (θ-major) order.
    pub fn all() -> impl Iterator<Item = State> {
        (0..super::microwave::phase_shifter::N_STATES).flat_map(|t| {
            (0..super::microwave::phase_shifter::N_STATES).map(move |p| State { theta: t, phi: p })
        })
    }

    /// Paper-style label, e.g. `L3L6`.
    pub fn label(&self) -> String {
        format!("L{}L{}", self.theta + 1, self.phi + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thirty_six_states() {
        assert_eq!(State::all().count(), 36);
    }

    #[test]
    fn labels_are_one_based() {
        assert_eq!(State { theta: 0, phi: 5 }.label(), "L1L6");
    }
}
