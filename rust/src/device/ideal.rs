//! Ideal (lossless, single-frequency) unit-cell model — eqs. (5)–(17).

use crate::math::c64::C64;
use crate::math::cmat::CMat;
use crate::microwave::Z0;

/// The 2×2 transfer matrix `t(θ, φ)` of eq. (5), mapping incident voltages
/// `(V1+, V4+)` to outgoing `(V2−, V3−)`:
///
/// `t = j·e^{-jθ/2} · [[e^{-jφ}·sin(θ/2), e^{-jφ}·cos(θ/2)],
///                     [cos(θ/2),         −sin(θ/2)]]`
pub fn t_matrix(theta: f64, phi: f64) -> CMat {
    let c = C64::J * C64::cis(-theta / 2.0);
    let (s, co) = ((theta / 2.0).sin(), (theta / 2.0).cos());
    let ph = C64::cis(-phi);
    CMat::from_rows(
        2,
        2,
        &[c * ph * s, c * ph * co, c * co, c * (-s)],
    )
}

/// The four device S-parameters of eqs. (6)–(9):
/// `(S21, S31, S24, S34)`.
pub fn s_params(theta: f64, phi: f64) -> (C64, C64, C64, C64) {
    let t = t_matrix(theta, phi);
    (t[(0, 0)], t[(1, 0)], t[(0, 1)], t[(1, 1)])
}

/// Ideal 4-port S-matrix of the device, ports ordered (P1, P2, P3, P4).
/// Inputs are matched and mutually isolated (the hybrids absorb nothing in
/// the ideal limit); the output-side 2×2 block is `t(θ, φ)`.
pub fn s4(theta: f64, phi: f64) -> crate::microwave::sparams::SMatrix {
    let t = t_matrix(theta, phi);
    let mut m = CMat::zeros(4, 4);
    // forward: column P1 → rows P2, P3 ; column P4 → rows P2, P3
    m[(1, 0)] = t[(0, 0)];
    m[(2, 0)] = t[(1, 0)];
    m[(1, 3)] = t[(0, 1)];
    m[(2, 3)] = t[(1, 1)];
    // reciprocity
    m[(0, 1)] = t[(0, 0)];
    m[(0, 2)] = t[(1, 0)];
    m[(3, 1)] = t[(0, 1)];
    m[(3, 2)] = t[(1, 1)];
    crate::microwave::sparams::SMatrix::new(m)
}

/// Voltage magnitudes at P2/P3 from each input — eqs. (10)–(13).
/// `p1_w`, `p4_w` are input powers in watts; returns `(V21, V31, V24, V34)`
/// as complex voltages (the paper plots their magnitudes in Fig. 3c).
pub fn voltage_transfer(theta: f64, phi: f64, p1_w: f64, p4_w: f64) -> (C64, C64, C64, C64) {
    let (s21, s31, s24, s34) = s_params(theta, phi);
    let v1 = (2.0 * Z0 * p1_w).sqrt();
    let v4 = (2.0 * Z0 * p4_w).sqrt();
    (s21 * v1, s31 * v1, s24 * v4, s34 * v4)
}

/// Output powers at P2/P3 for in-phase inputs — eqs. (14)–(17).
/// Returns `(P2, P3)` in watts.
pub fn power_transfer(theta: f64, phi: f64, p1_w: f64, p4_w: f64) -> (f64, f64) {
    let (v21, v31, v24, v34) = voltage_transfer(theta, phi, p1_w, p4_w);
    let p2 = (v21 + v24).norm_sqr() / (2.0 * Z0);
    let p3 = (v31 + v34).norm_sqr() / (2.0 * Z0);
    (p2, p3)
}

/// Closed-form eq. (16)–(17) for cross-checking `power_transfer`:
/// `P2 = (P1+P4)·sin²(θ/2 + Δ)`, `P3 = (P1+P4)·cos²(θ/2 + Δ)`,
/// `Δ = acos(√P1/√(P1+P4))`.
pub fn power_transfer_closed_form(theta: f64, p1_w: f64, p4_w: f64) -> (f64, f64) {
    let total = p1_w + p4_w;
    let delta = (p1_w.sqrt() / total.sqrt()).acos();
    let p2 = total * (theta / 2.0 + delta).sin().powi(2);
    let p3 = total * (theta / 2.0 + delta).cos().powi(2);
    (p2, p3)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::deg;
    use std::f64::consts::PI;

    #[test]
    fn t_is_unitary_everywhere() {
        for k in 0..24 {
            let th = k as f64 * PI / 6.0;
            let ph = k as f64 * 0.3;
            assert!(t_matrix(th, ph).is_unitary(1e-12), "θ={th} φ={ph}");
        }
    }

    #[test]
    fn cross_state_at_theta_zero() {
        // θ=0: |S21|=0, |S31|=1 (all power crosses).
        let (s21, s31, _, s34) = s_params(0.0, 0.0);
        assert!(s21.abs() < 1e-12);
        assert!((s31.abs() - 1.0).abs() < 1e-12);
        assert!(s34.abs() < 1e-12);
    }

    #[test]
    fn bar_state_at_theta_pi() {
        // θ=π: |S21|=1, |S31|=0 (bar state).
        let (s21, s31, s24, _) = s_params(PI, 0.0);
        assert!((s21.abs() - 1.0).abs() < 1e-12);
        assert!(s31.abs() < 1e-12);
        assert!(s24.abs() < 1e-12);
    }

    #[test]
    fn phi_only_phases_port2_row() {
        let (a21, a31, a24, a34) = s_params(1.1, 0.0);
        let (b21, b31, b24, b34) = s_params(1.1, 0.8);
        // magnitudes unchanged
        assert!((a21.abs() - b21.abs()).abs() < 1e-12);
        assert!((a24.abs() - b24.abs()).abs() < 1e-12);
        // port-2 row picks up exactly e^{-jφ}
        assert!((b21 / a21 - C64::cis(-0.8)).abs() < 1e-12);
        assert!((b24 / a24 - C64::cis(-0.8)).abs() < 1e-12);
        // port-3 row untouched
        assert!((a31 - b31).abs() < 1e-12);
        assert!((a34 - b34).abs() < 1e-12);
    }

    #[test]
    fn eq6_to_9_forms() {
        let (theta, phi) = (deg(104.0), deg(53.0));
        let c = C64::J * C64::cis(-theta / 2.0);
        let (s21, s31, s24, s34) = s_params(theta, phi);
        assert!((s21 - c * C64::cis(-phi) * (theta / 2.0).sin()).abs() < 1e-12);
        assert!((s31 - c * (theta / 2.0).cos()).abs() < 1e-12);
        assert!((s24 - c * C64::cis(-phi) * (theta / 2.0).cos()).abs() < 1e-12);
        assert!((s34 + c * (theta / 2.0).sin()).abs() < 1e-12);
    }

    #[test]
    fn power_conserved() {
        let (p2, p3) = power_transfer(1.3, 0.4, 0.5e-3, 1.5e-3);
        assert!((p2 + p3 - 2.0e-3).abs() < 1e-12, "p2+p3 = {}", p2 + p3);
    }

    #[test]
    fn power_matches_closed_form_eq16_17() {
        // Paper's Fig. 3(d) setup: P1 = 0.5 mW, P4 = 1.5 mW, in phase.
        for k in 0..36 {
            let th = k as f64 * 2.0 * PI / 36.0;
            let (p2, p3) = power_transfer(th, 0.0, 0.5e-3, 1.5e-3);
            let (c2, c3) = power_transfer_closed_form(th, 0.5e-3, 1.5e-3);
            assert!((p2 - c2).abs() < 1e-9, "θ={th}: {p2} vs {c2}");
            assert!((p3 - c3).abs() < 1e-9, "θ={th}: {p3} vs {c3}");
        }
    }

    #[test]
    fn fig3d_extremes() {
        // With P1=0.5, P4=1.5 mW: max P2 = P1+P4 = 2 mW when θ/2+Δ = π/2.
        let total: f64 = 2.0e-3;
        let delta = ((0.5e-3f64).sqrt() / total.sqrt()).acos();
        let th_max = 2.0 * (PI / 2.0 - delta);
        let (p2, p3) = power_transfer(th_max, 0.0, 0.5e-3, 1.5e-3);
        assert!((p2 - total).abs() < 1e-9);
        assert!(p3.abs() < 1e-9);
    }

    #[test]
    fn s4_reciprocal_and_forward_block_matches_t() {
        let s = s4(0.9, 0.3);
        assert!(s.is_reciprocal(1e-12));
        let t = t_matrix(0.9, 0.3);
        assert_eq!(s.s(1, 0), t[(0, 0)]);
        assert_eq!(s.s(2, 0), t[(1, 0)]);
        assert_eq!(s.s(1, 3), t[(0, 1)]);
        assert_eq!(s.s(2, 3), t[(1, 1)]);
    }

    #[test]
    fn voltage_transfer_scales_with_sqrt_power() {
        let (v21a, ..) = voltage_transfer(1.0, 0.0, 1.0e-3, 1.0e-3);
        let (v21b, ..) = voltage_transfer(1.0, 0.0, 4.0e-3, 1.0e-3);
        assert!((v21b.abs() / v21a.abs() - 2.0).abs() < 1e-12);
    }
}
