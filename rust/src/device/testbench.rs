//! Power-domain test bench — the experiment rig of Figs. 10–12.
//!
//! "Feed RF power into ports P1 and P4, measure output power at P2 and P3":
//! inputs are *voltage magnitudes* (in-phase excitation), outputs are
//! detected powers with a realistic detector noise floor. This is the
//! analog forward pass the RFNN training loop sees — a physical S-matrix
//! application, never a weight lookup.

use super::State;
use crate::math::c64::C64;
use crate::math::cmat::CMat;
use crate::math::rng::Rng;
use crate::microwave::Z0;

/// RF power-detector model.
#[derive(Clone, Copy, Debug)]
pub struct Detector {
    /// Noise floor (W). Paper §V quotes −60 dBm sensitivity → 1e-9 mW.
    pub floor_w: f64,
    /// Relative measurement noise (σ, fraction of reading).
    pub rel_noise: f64,
}

impl Default for Detector {
    fn default() -> Self {
        Detector { floor_w: 1e-12, rel_noise: 0.002 }
    }
}

/// A measurement rig around any 2×2 forward transfer block provider.
#[derive(Clone, Debug)]
pub struct TestBench<F: Fn(State) -> CMat> {
    /// Maps device state → forward transfer block `[[S21,S24],[S31,S34]]`.
    pub transfer: F,
    pub detector: Detector,
    /// Seed for detector noise (0 → noiseless).
    pub seed: u64,
}

impl<F: Fn(State) -> CMat> TestBench<F> {
    /// Create a bench with the default detector.
    pub fn new(transfer: F, seed: u64) -> Self {
        TestBench { transfer, detector: Detector::default(), seed }
    }

    /// Excite with in-phase voltage magnitudes `(v1, v4)` (volts) in state
    /// `st`; return detected powers `(p2, p3)` in watts.
    pub fn measure_powers(&self, st: State, v1: f64, v4: f64) -> (f64, f64) {
        let t = (self.transfer)(st);
        let vin = [C64::real(v1), C64::real(v4)];
        let vout = t.matvec(&vin);
        let p2 = vout[0].norm_sqr() / (2.0 * Z0);
        let p3 = vout[1].norm_sqr() / (2.0 * Z0);
        if self.seed == 0 {
            return (p2, p3);
        }
        let mut rng = Rng::new(
            self.seed
                ^ v1.to_bits().rotate_left(7)
                ^ v4.to_bits().rotate_left(31)
                ^ ((st.theta as u64) << 16 | st.phi as u64),
        );
        let noisy = |p: f64, r: &mut Rng| {
            (p * (1.0 + self.detector.rel_noise * r.normal()) + self.detector.floor_w * r.uniform())
                .max(0.0)
        };
        (noisy(p2, &mut rng), noisy(p3, &mut rng))
    }

    /// Detected output *voltage magnitudes* `(|v2|, |v3|)` (volts) — what
    /// the RFNN hidden layer consumes (the abs(·) activation, eq. 20).
    pub fn measure_voltages(&self, st: State, v1: f64, v4: f64) -> (f64, f64) {
        let (p2, p3) = self.measure_powers(st, v1, v4);
        ((2.0 * Z0 * p2).sqrt(), (2.0 * Z0 * p3).sqrt())
    }

    /// Sweep the full input space on an `n×n` grid over `[0, vmax]²`
    /// (the paper uses 11×11, 0–1 V) — returns row-major `(v2, v3)` grids
    /// indexed `[i_v1][j_v4]`.
    pub fn grid_sweep(&self, st: State, vmax: f64, n: usize) -> Vec<Vec<(f64, f64)>> {
        (0..n)
            .map(|i| {
                let v1 = vmax * i as f64 / (n - 1) as f64;
                (0..n)
                    .map(|j| {
                        let v4 = vmax * j as f64 / (n - 1) as f64;
                        self.measure_voltages(st, v1, v4)
                    })
                    .collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::ideal;
    use crate::device::vna::MeasuredUnitCell;

    fn ideal_bench(theta: f64, phi: f64) -> TestBench<impl Fn(State) -> CMat> {
        TestBench::new(move |_st| ideal::t_matrix(theta, phi), 0)
    }

    #[test]
    fn noiseless_matches_eq16() {
        let b = ideal_bench(1.1, 0.0);
        // v = sqrt(2 Z0 P): P1 = 0.5 mW, P4 = 1.5 mW.
        let v1 = (2.0f64 * Z0 * 0.5e-3).sqrt();
        let v4 = (2.0f64 * Z0 * 1.5e-3).sqrt();
        let (p2, p3) = b.measure_powers(State { theta: 0, phi: 0 }, v1, v4);
        let (c2, c3) = ideal::power_transfer_closed_form(1.1, 0.5e-3, 1.5e-3);
        assert!((p2 - c2).abs() < 1e-12);
        assert!((p3 - c3).abs() < 1e-12);
    }

    #[test]
    fn voltages_are_abs_of_complex_sum() {
        let b = ideal_bench(0.8, 0.5);
        let (v2, v3) = b.measure_voltages(State { theta: 0, phi: 0 }, 0.3, 0.7);
        let t = ideal::t_matrix(0.8, 0.5);
        let out = t.matvec(&[C64::real(0.3), C64::real(0.7)]);
        assert!((v2 - out[0].abs()).abs() < 1e-12);
        assert!((v3 - out[1].abs()).abs() < 1e-12);
    }

    #[test]
    fn noise_is_deterministic_per_seed() {
        let dev = MeasuredUnitCell::fabricate(11);
        let b = TestBench::new(move |st| dev.t_block(st), 42);
        let a = b.measure_powers(State { theta: 1, phi: 0 }, 0.5, 0.5);
        let c = b.measure_powers(State { theta: 1, phi: 0 }, 0.5, 0.5);
        assert_eq!(a, c);
    }

    #[test]
    fn grid_sweep_shape_and_monotonicity() {
        let b = ideal_bench(1.0, 0.0);
        let g = b.grid_sweep(State { theta: 0, phi: 0 }, 1.0, 11);
        assert_eq!(g.len(), 11);
        assert_eq!(g[0].len(), 11);
        // More input power → more total output power.
        let p = |v: (f64, f64)| v.0 * v.0 + v.1 * v.1;
        assert!(p(g[10][10]) > p(g[5][5]));
        assert!(p(g[0][0]) < 1e-18);
    }

    #[test]
    fn detector_floor_bounds_small_signals() {
        let dev = MeasuredUnitCell::fabricate(12);
        let b = TestBench::new(move |st| dev.t_block(st), 9);
        let (p2, p3) = b.measure_powers(State { theta: 0, phi: 0 }, 0.0, 0.0);
        assert!(p2 >= 0.0 && p3 >= 0.0);
        assert!(p2 < 2.0 * b.detector.floor_w && p3 < 2.0 * b.detector.floor_w);
    }

    #[test]
    fn power_conservation_under_measured_device() {
        // A passive measured device never outputs more power than input.
        let dev = MeasuredUnitCell::fabricate(13);
        let b = TestBench::new(move |st| dev.t_block(st), 0);
        for st in State::all() {
            let (p2, p3) = b.measure_powers(st, 0.5, 0.8);
            let pin = (0.5f64 * 0.5 + 0.8 * 0.8) / (2.0 * Z0);
            assert!(p2 + p3 <= pin * 1.01, "{}: {} > {}", st.label(), p2 + p3, pin);
        }
    }
}
