//! Circuit-level unit-cell model ("simulation" fidelity).
//!
//! Physical assembly per Fig. 2/Fig. 4: branch-line hybrid → {θ phase
//! shifter ∥ padded reference arm} → branch-line hybrid → φ phase shifter
//! on P2 (P3 has a plain output trace). All pieces are microstrip models on
//! the prototype substrate; the 4-port S-matrix is produced for any
//! frequency and any of the 36 states.
//!
//! Design note: the reference arm carries a matched pad equal to the phase
//! shifter's common-path loss so the interferometer arms stay amplitude-
//! balanced (otherwise the switch insertion loss alone would cap the
//! extinction ratio ~12 dB below theory). The *virtual VNA* then perturbs
//! this balance to produce measurement-like imperfection.

use super::State;
use crate::microwave::hybrid::BranchLineHybrid;
use crate::microwave::microstrip::{Microstrip, Substrate};
use crate::microwave::netlist::{Netlist, PortRef};
use crate::microwave::phase_shifter::{SwitchModel, SwitchedLinePhaseShifter};
use crate::microwave::sparams::SMatrix;
use crate::microwave::{F0, Z0};

/// Tunable imperfections applied to a [`UnitCellCircuit`] (used by the
/// virtual VNA to emulate fabrication spread; all zero for the nominal
/// "simulation" device).
#[derive(Clone, Copy, Debug, Default)]
pub struct Imperfections {
    /// Multiplicative error on every phase-shifter path length (e.g. 0.01 = +1 %).
    pub theta_len_err: [f64; 6],
    /// Multiplicative error on the φ shifter path lengths.
    pub phi_len_err: [f64; 6],
    /// Reference-arm amplitude imbalance (linear, 1.0 = balanced).
    pub ref_arm_gain: f64,
    /// Extra per-hybrid amplitude error (linear multiplier on through/coupled).
    pub hybrid_gain_err: f64,
}

/// The physical 2×2 unit cell.
#[derive(Clone, Debug)]
pub struct UnitCellCircuit {
    hybrid: BranchLineHybrid,
    theta_ps: SwitchedLinePhaseShifter,
    phi_ps: SwitchedLinePhaseShifter,
    /// Reference arm between the hybrids (same common length as the PS).
    ref_arm: Microstrip,
    /// Plain output trace on P3 (balances the φ shifter's common delay only
    /// roughly — like the prototype, P2/P3 output paths are not identical).
    out_trace: Microstrip,
    /// Amplitude pad applied to the reference arm (see module docs).
    ref_pad: f64,
    imp: Imperfections,
}

impl UnitCellCircuit {
    /// The nominal prototype: RO4360G2, 50 Ω, f0 = 2 GHz, JSW6-33DR+ switches.
    pub fn prototype() -> Self {
        Self::new(Substrate::ro4360g2(), SwitchModel::jsw6_33dr())
    }

    /// Build a unit cell on the given substrate and switch model.
    pub fn new(sub: Substrate, switch: SwitchModel) -> Self {
        let hybrid = BranchLineHybrid::design(sub, Z0, F0);
        let theta_ps = SwitchedLinePhaseShifter::design(sub, Z0, F0, switch);
        let phi_ps = SwitchedLinePhaseShifter::design(sub, Z0, F0, switch);
        let ref_arm = Microstrip::with_electrical_length(sub, Z0, std::f64::consts::PI, F0);
        let out_trace = Microstrip::with_electrical_length(sub, Z0, 0.3, F0);
        // Pad the reference arm by the PS common-path loss at f0 (state L1's
        // loss minus its excess line loss ≈ switch² + common line).
        let ps_common_db = theta_ps.insertion_loss_db(F0, 0)
            - (theta_ps.path_length(0) - ref_arm.length) * ref_arm.alpha(F0) * 8.685_889_638;
        let ref_line_db = ref_arm.alpha(F0) * ref_arm.length * 8.685_889_638;
        let ref_pad = crate::math::db_to_mag(-(ps_common_db - ref_line_db).max(0.0));
        UnitCellCircuit {
            hybrid,
            theta_ps,
            phi_ps,
            ref_arm,
            out_trace,
            ref_pad,
            imp: Imperfections { ref_arm_gain: 1.0, ..Default::default() },
        }
    }

    /// Apply an imperfection set (virtual-VNA fabrication spread).
    pub fn with_imperfections(mut self, imp: Imperfections) -> Self {
        self.imp = imp;
        self
    }

    /// Access the θ phase shifter (for Table I reporting).
    pub fn theta_shifter(&self) -> &SwitchedLinePhaseShifter {
        &self.theta_ps
    }

    /// Total DC power drawn by the four switches (W) — Table II input.
    pub fn dc_power(&self) -> f64 {
        self.theta_ps.dc_power() + self.phi_ps.dc_power()
    }

    /// Phase-shifter 2-port with length imperfection folded in: we emulate
    /// an etched-length error by adding the corresponding extra electrical
    /// delay (and its microscopic loss) as a short line section.
    fn ps_sparams(&self, ps: &SwitchedLinePhaseShifter, err: f64, f: f64, state: usize) -> SMatrix {
        let s = ps.sparams(f, state);
        if err == 0.0 {
            return s;
        }
        let dl = ps.path_length(state) * err;
        let extra = Microstrip { length: dl.abs(), ..self.ref_arm };
        let phase = extra.beta(f) * dl; // signed
        let amp = (-extra.alpha(f) * dl.abs()).exp();
        SMatrix::cascade(&s, &SMatrix::line(phase, amp))
    }

    /// Full 4-port S-matrix, ports ordered (P1, P2, P3, P4), at frequency
    /// `f` and device state `st`.
    pub fn sparams(&self, f: f64, st: State) -> SMatrix {
        let mut h_s = self.hybrid.sparams(f);
        if self.imp.hybrid_gain_err != 0.0 {
            let g = 1.0 + self.imp.hybrid_gain_err;
            h_s = SMatrix::new(h_s.mat().scale(crate::math::c64::C64::real(g)));
        }
        let theta_s =
            self.ps_sparams(&self.theta_ps, self.imp.theta_len_err[st.theta], f, st.theta);
        let phi_s = self.ps_sparams(&self.phi_ps, self.imp.phi_len_err[st.phi], f, st.phi);
        // Reference arm: plain line + balancing pad (+ imbalance knob). The
        // pad also carries the θ-shifter's static switch-path phase so the
        // differential phase between the arms is exactly Table I at f0 —
        // the prototype's reference trace is length-trimmed the same way.
        let ref_gain =
            self.ref_pad * if self.imp.ref_arm_gain == 0.0 { 1.0 } else { self.imp.ref_arm_gain };
        let switch_static = 2.0 * self.theta_ps.switch.path_phase * (f / F0);
        let arm = {
            let line = self.ref_arm.sparams(f, Z0);
            SMatrix::cascade(&line, &SMatrix::line(switch_static, ref_gain))
        };
        let out3 = self.out_trace.sparams(f, Z0);

        let mut nl = Netlist::new();
        let h1 = nl.add(h_s.clone());
        let h2 = nl.add(h_s);
        let tps = nl.add(theta_s);
        let rarm = nl.add(arm);
        let pps = nl.add(phi_s);
        let otr = nl.add(out3);
        // Paper port convention (0-based locals): hybrid 0=P1-side in,
        // 1=through out, 2=coupled out, 3=P4-side in.
        nl.join(h1, 1, tps, 0); // θ arm
        nl.join(tps, 1, h2, 0);
        nl.join(h1, 2, rarm, 0); // reference arm
        nl.join(rarm, 1, h2, 3);
        nl.join(h2, 1, pps, 0); // φ shifter on P2
        nl.join(h2, 2, otr, 0); // plain trace on P3
        nl.reduce(&[
            PortRef { net: h1, port: 0 },  // P1
            PortRef { net: pps, port: 1 }, // P2
            PortRef { net: otr, port: 1 }, // P3
            PortRef { net: h1, port: 3 },  // P4
        ])
    }

    /// The forward 2×2 transfer block `[[S21, S24], [S31, S34]]` at `f`.
    pub fn t_block(&self, f: f64, st: State) -> crate::math::cmat::CMat {
        let s = self.sparams(f, st);
        crate::math::cmat::CMat::from_rows(
            2,
            2,
            &[s.s(1, 0), s.s(1, 3), s.s(2, 0), s.s(2, 3)],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::ideal;
    use crate::math::deg;
    use crate::microwave::phase_shifter::TABLE_I_DEG;

    fn cell() -> UnitCellCircuit {
        UnitCellCircuit::prototype()
    }

    #[test]
    fn passive_and_reciprocal_all_states() {
        let c = cell();
        let probes =
            [State { theta: 0, phi: 0 }, State { theta: 3, phi: 5 }, State { theta: 5, phi: 2 }];
        for st in probes {
            let s = c.sparams(F0, st);
            assert!(s.is_passive(1e-6), "{}", st.label());
            assert!(s.is_reciprocal(1e-9), "{}", st.label());
        }
    }

    #[test]
    fn magnitudes_track_ideal_theta_dependence() {
        // Fig. 6's claim: |S21| etc. follow sin/cos(θ/2) with extra loss.
        let c = cell();
        for (n, &th_deg) in TABLE_I_DEG.iter().enumerate() {
            let st = State { theta: n, phi: 0 };
            let s = c.sparams(F0, st);
            let (i21, i31, ..) = ideal::s_params(deg(th_deg), 0.0);
            // Circuit magnitudes = ideal × overall insertion loss (≈3–5 dB).
            let loss21 = s.s(1, 0).abs() / i21.abs().max(1e-9);
            let loss31 = s.s(2, 0).abs() / i31.abs().max(1e-9);
            assert!(
                (0.3..1.0).contains(&loss21),
                "state {n}: |S21| ratio {loss21} (circ {} ideal {})",
                s.s(1, 0).abs(),
                i21.abs()
            );
            assert!((0.3..1.0).contains(&loss31), "state {n}: |S31| ratio {loss31}");
        }
    }

    #[test]
    fn theta_states_move_power_from_cross_to_bar() {
        let c = cell();
        // As θ grows (L1→L6), |S21| (bar-ish) grows and |S31| shrinks.
        let m = |n: usize| {
            let s = c.sparams(F0, State { theta: n, phi: 0 });
            (s.s(1, 0).abs(), s.s(2, 0).abs())
        };
        let (s21_l1, s31_l1) = m(0);
        let (s21_l6, s31_l6) = m(5);
        assert!(s21_l6 > s21_l1, "S21 should increase L1→L6: {s21_l1} → {s21_l6}");
        assert!(s31_l6 < s31_l1, "S31 should decrease L1→L6: {s31_l1} → {s31_l6}");
    }

    #[test]
    fn phi_changes_port2_phase_not_magnitudes() {
        let c = cell();
        let a = c.sparams(F0, State { theta: 2, phi: 0 });
        let b = c.sparams(F0, State { theta: 2, phi: 4 });
        assert!((a.s(1, 0).abs() - b.s(1, 0).abs()).abs() < 0.02);
        // |S31| is only *nearly* φ-independent in the circuit model: the φ
        // shifter's finite return loss re-enters hybrid B and leaks to P3.
        assert!((a.s(2, 0).abs() - b.s(2, 0).abs()).abs() < 0.01);
        let dphi = crate::math::wrap_angle(b.s(1, 0).arg() - a.s(1, 0).arg());
        // φ L1→L5: expected extra delay = 135° − 29° = 106° (sign negative).
        assert!(
            (dphi.to_degrees() + (TABLE_I_DEG[4] - TABLE_I_DEG[0])).abs() < 8.0,
            "Δφ = {}°",
            dphi.to_degrees()
        );
    }

    #[test]
    fn ports_are_matched_at_f0() {
        let c = cell();
        let s = c.sparams(F0, State { theta: 0, phi: 0 });
        for p in 0..4 {
            let rl = -20.0 * s.s(p, p).abs().log10();
            assert!(rl > 10.0, "port {p} return loss {rl} dB");
        }
    }

    #[test]
    fn response_degrades_off_center() {
        let c = cell();
        let st = State { theta: 2, phi: 0 };
        let at = |f: f64| c.sparams(f, st).s(0, 0).abs();
        assert!(at(1.5e9) > 2.0 * at(F0), "S11 {} vs {}", at(1.5e9), at(F0));
    }

    #[test]
    fn imperfections_shift_response() {
        let nominal = cell().sparams(F0, State { theta: 1, phi: 1 });
        let mut imp = Imperfections { ref_arm_gain: 0.95, ..Default::default() };
        imp.theta_len_err[1] = 0.02;
        let pert = cell().with_imperfections(imp).sparams(F0, State { theta: 1, phi: 1 });
        let d = nominal.mat().sub(pert.mat()).max_abs();
        assert!(d > 1e-3, "imperfections must visibly change S ({d})");
        assert!(d < 0.3, "but not unrecognizably ({d})");
    }
}
