//! Nonlinear RF activation hardware — the paper's §V extension path:
//! "power detectors and transistors can be used to design non-linear
//! activation function and additional static voltage may serve as bias for
//! each neuron", enabling multi-layer RFNNs without per-layer digital
//! post-processing.
//!
//! Behavioral models, not transistor-level SPICE: what matters for the
//! network studies is the transfer curve family and its bias knob.
//!
//! * [`DiodeDetector`] — square-law power detector with responsivity,
//!   video-resistance compression and noise floor: the natural "|·|²-ish"
//!   neuron the paper's own measurement chain already implies.
//! * [`TransistorLimiter`] — a biased FET amplifier driven into
//!   compression: tanh-like saturation with a bias-adjustable knee (the
//!   "static voltage as neuron bias").
//! * [`RectifierNeuron`] — detector + bias + re-modulation: an RF-domain
//!   leaky-ReLU usable between two linear mesh layers.

use crate::microwave::Z0;

/// Square-law diode power detector.
#[derive(Clone, Copy, Debug)]
pub struct DiodeDetector {
    /// Small-signal responsivity (V/W).
    pub responsivity: f64,
    /// Output compression point (V): output saturates toward this level.
    pub v_sat: f64,
    /// Input-referred noise floor (W).
    pub floor_w: f64,
}

impl Default for DiodeDetector {
    fn default() -> Self {
        // Typical Schottky detector: ~1 mV/µW, ~1 V saturation, −60 dBm floor.
        DiodeDetector { responsivity: 1.0e3, v_sat: 1.0, floor_w: 1.0e-9 }
    }
}

impl DiodeDetector {
    /// DC output voltage for an RF input of amplitude `v_in` (volts, 50 Ω).
    pub fn detect(&self, v_in: f64) -> f64 {
        let p_in = v_in * v_in / (2.0 * Z0);
        if p_in < self.floor_w {
            return 0.0;
        }
        let linear = self.responsivity * p_in;
        // Soft compression toward v_sat.
        self.v_sat * (linear / self.v_sat).tanh()
    }
}

/// FET amplifier driven into compression: tanh transfer with gain and a
/// bias-controlled operating point.
#[derive(Clone, Copy, Debug)]
pub struct TransistorLimiter {
    /// Small-signal voltage gain.
    pub gain: f64,
    /// Output saturation amplitude (V).
    pub v_sat: f64,
    /// Gate bias offset (V) — shifts the knee (the neuron's threshold).
    pub bias: f64,
}

impl TransistorLimiter {
    /// Output amplitude for input amplitude `v_in`.
    pub fn transfer(&self, v_in: f64) -> f64 {
        self.v_sat * ((self.gain * (v_in - self.bias)) / self.v_sat).tanh()
    }
}

/// An RF-domain neuron: detect |·|, apply bias, clamp at zero (the diode
/// only conducts one way), optionally leak — a hardware leaky-ReLU on the
/// detected envelope, re-modulated onto the carrier for the next layer.
#[derive(Clone, Copy, Debug)]
pub struct RectifierNeuron {
    pub detector: DiodeDetector,
    /// Static bias voltage subtracted after detection (V).
    pub bias: f64,
    /// Leak slope below threshold (0 = hard ReLU).
    pub leak: f64,
    /// Re-modulation gain back to RF amplitude.
    pub remod_gain: f64,
}

impl Default for RectifierNeuron {
    fn default() -> Self {
        RectifierNeuron {
            detector: DiodeDetector::default(),
            bias: 0.0,
            leak: 0.01,
            remod_gain: 1.0,
        }
    }
}

impl RectifierNeuron {
    /// Envelope-domain activation: returns the re-modulated RF amplitude.
    pub fn activate(&self, v_in: f64) -> f64 {
        let v_det = self.detector.detect(v_in) - self.bias;
        let rectified = if v_det >= 0.0 { v_det } else { self.leak * v_det };
        self.remod_gain * rectified
    }

    /// Apply to a whole layer of detected magnitudes.
    pub fn activate_layer(&self, v: &[f64]) -> Vec<f64> {
        v.iter().map(|&x| self.activate(x)).collect()
    }
}

/// A two-analog-layer RFNN block: mesh → RF neurons → mesh, no digital
/// processing in between (the §V multi-layer vision). The caller supplies
/// the two composed mesh matrices.
pub fn two_layer_analog_forward(
    m1: &crate::math::cmat::CMat,
    neurons: &RectifierNeuron,
    m2: &crate::math::cmat::CMat,
    x: &[f64],
) -> Vec<f64> {
    use crate::math::c64::C64;
    let xc: Vec<C64> = x.iter().map(|&v| C64::real(v)).collect();
    let h1: Vec<f64> = m1.matvec(&xc).iter().map(|z| z.abs()).collect();
    let a1 = neurons.activate_layer(&h1);
    let a1c: Vec<C64> = a1.iter().map(|&v| C64::real(v)).collect();
    m2.matvec(&a1c).iter().map(|z| z.abs()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detector_is_square_law_at_small_signal() {
        let d = DiodeDetector::default();
        let v1 = d.detect(0.01);
        let v2 = d.detect(0.02); // 2× amplitude → 4× power
        assert!((v2 / v1 - 4.0).abs() < 0.01, "ratio {}", v2 / v1);
    }

    #[test]
    fn detector_saturates() {
        let d = DiodeDetector::default();
        let big = d.detect(100.0);
        assert!(big <= d.v_sat * 1.0001);
        assert!(d.detect(200.0) <= d.v_sat * 1.0001);
    }

    #[test]
    fn detector_floor_gates_small_signals() {
        let d = DiodeDetector::default();
        // −70 dBm ≈ 1e-10 W → below the −60 dBm floor.
        let v_in = (2.0 * Z0 * 1.0e-10f64).sqrt();
        assert_eq!(d.detect(v_in), 0.0);
    }

    #[test]
    fn limiter_bias_shifts_knee() {
        let base = TransistorLimiter { gain: 10.0, v_sat: 1.0, bias: 0.0 };
        let biased = TransistorLimiter { bias: 0.1, ..base };
        assert!((base.transfer(0.1) - biased.transfer(0.2)).abs() < 1e-12);
        assert!(biased.transfer(0.1).abs() < 1e-9);
    }

    #[test]
    fn rectifier_neuron_is_leaky_relu_on_envelope() {
        let n = RectifierNeuron { bias: 0.2, leak: 0.1, ..Default::default() };
        // Above threshold: positive output growing with input.
        let hi = n.activate(0.5);
        assert!(hi > 0.0);
        // Below threshold: small negative leak.
        let lo = n.activate(0.05);
        assert!(lo < 0.0 && lo.abs() < 0.1 * n.bias + 1e-9, "lo = {lo}");
    }

    #[test]
    fn two_layer_block_is_nonlinear() {
        use crate::math::cmat::CMat;
        use crate::mesh::propagate::{DiscreteMesh, MeshBackend};
        let mesh1 = DiscreteMesh::new(4, MeshBackend::Ideal);
        let mut mesh2 = DiscreteMesh::new(4, MeshBackend::Ideal);
        mesh2.set_state(2, crate::device::State { theta: 3, phi: 1 });
        let m1: CMat = mesh1.matrix().clone();
        let m2: CMat = mesh2.matrix().clone();
        let neurons = RectifierNeuron { bias: 0.05, ..Default::default() };
        let x = [0.2, 0.1, 0.3, 0.05];
        let y1 = two_layer_analog_forward(&m1, &neurons, &m2, &x);
        // Scaling the input by 2 must NOT scale the output by 2 (the bias
        // breaks homogeneity) — i.e. the block is genuinely nonlinear.
        let x2: Vec<f64> = x.iter().map(|&v| v * 2.0).collect();
        let y2 = two_layer_analog_forward(&m1, &neurons, &m2, &x2);
        let ratio = y2[0] / y1[0];
        assert!((ratio - 2.0).abs() > 0.05, "block looks linear (ratio {ratio})");
        assert!(y1.iter().all(|v| v.is_finite()));
    }
}
