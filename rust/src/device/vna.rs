//! Virtual VNA — the stand-in for the paper's *measured* prototype.
//!
//! A [`MeasuredUnitCell`] is a circuit-level unit cell with a seeded,
//! device-specific fabrication perturbation (etch-length error per switched
//! path, hybrid amplitude error, arm imbalance) plus per-point measurement
//! noise at a realistic VNA noise floor. The paper's Figs. 6, 9, 10, 12 and
//! 15 are all driven by measured S-parameters; this module produces data
//! with the same signature (magnitudes slightly below theory, small phase
//! deviations) so those experiments exercise the identical code path.

use super::circuit::{Imperfections, UnitCellCircuit};
use super::State;
use crate::math::c64::C64;
use crate::math::cmat::CMat;
use crate::math::rng::Rng;
use crate::microwave::sparams::SMatrix;
use crate::microwave::touchstone::Touchstone;
use crate::microwave::F0;

/// Magnitude of the fabrication spread (one standard deviation).
#[derive(Clone, Copy, Debug)]
pub struct FabSpread {
    /// Relative etched-length error per switched path (σ).
    pub len_err: f64,
    /// Hybrid amplitude error (σ, linear).
    pub hybrid_err: f64,
    /// Reference-arm gain error (σ, linear).
    pub arm_err: f64,
    /// VNA measurement noise floor relative to 0 dB (linear σ per S entry).
    pub noise: f64,
}

impl Default for FabSpread {
    fn default() -> Self {
        // Calibrated to reproduce the paper's qualitative gap between
        // simulation and measurement in Fig. 6 (≈0.5–1 dB magnitude
        // reduction, few-degree phase deviation).
        FabSpread { len_err: 0.012, hybrid_err: 0.02, arm_err: 0.03, noise: 0.003 }
    }
}

/// A specific fabricated-and-measured device instance.
#[derive(Clone, Debug)]
pub struct MeasuredUnitCell {
    cell: UnitCellCircuit,
    noise: f64,
    seed: u64,
}

impl MeasuredUnitCell {
    /// "Fabricate" device `seed` with the default spread and hook it to the
    /// virtual VNA.
    pub fn fabricate(seed: u64) -> Self {
        Self::fabricate_with(seed, FabSpread::default())
    }

    /// Fabricate with an explicit spread (σ = 0 → noiseless nominal device).
    pub fn fabricate_with(seed: u64, spread: FabSpread) -> Self {
        let mut rng = Rng::new(seed ^ 0xFAB0_DE71);
        let mut imp = Imperfections {
            ref_arm_gain: 1.0 + spread.arm_err * rng.normal(),
            ..Default::default()
        };
        for i in 0..6 {
            imp.theta_len_err[i] = spread.len_err * rng.normal();
            imp.phi_len_err[i] = spread.len_err * rng.normal();
        }
        imp.hybrid_gain_err = spread.hybrid_err * rng.normal();
        MeasuredUnitCell {
            cell: UnitCellCircuit::prototype().with_imperfections(imp),
            noise: spread.noise,
            seed,
        }
    }

    /// Single measured S-matrix at frequency `f`, state `st`. Measurement
    /// noise is deterministic in `(seed, f, state)` so repeated "sweeps"
    /// agree (the VNA averages out trace noise).
    pub fn measure(&self, f: f64, st: State) -> SMatrix {
        let s = self.cell.sparams(f, st);
        let mut rng = Rng::new(
            self.seed ^ (f.to_bits().rotate_left(17)) ^ ((st.theta as u64) << 8 | st.phi as u64),
        );
        let m = CMat::from_fn(4, 4, |i, j| {
            s.s(i, j) + C64::new(rng.normal() * self.noise, rng.normal() * self.noise)
        });
        SMatrix::new(m)
    }

    /// Measured forward transfer block `[[S21, S24],[S31, S34]]` at `f0`.
    pub fn t_block(&self, st: State) -> CMat {
        let s = self.measure(F0, st);
        CMat::from_rows(2, 2, &[s.s(1, 0), s.s(1, 3), s.s(2, 0), s.s(2, 3)])
    }

    /// Full frequency sweep for one state → Touchstone dataset
    /// (the `.s4p` a real VNA would export).
    pub fn sweep(&self, st: State, f_start: f64, f_stop: f64, points: usize) -> Touchstone {
        assert!(points >= 2);
        let mut ts = Touchstone::new(4, crate::microwave::Z0);
        for k in 0..points {
            let f = f_start + (f_stop - f_start) * k as f64 / (points - 1) as f64;
            ts.push(f, self.measure(f, st));
        }
        ts
    }

    /// The underlying (perturbed) circuit — for tests and ablations.
    pub fn circuit(&self) -> &UnitCellCircuit {
        &self.cell
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::ideal;
    use crate::math::deg;
    use crate::microwave::phase_shifter::TABLE_I_DEG;

    #[test]
    fn measurement_is_deterministic() {
        let dev = MeasuredUnitCell::fabricate(7);
        let a = dev.measure(F0, State { theta: 2, phi: 1 });
        let b = dev.measure(F0, State { theta: 2, phi: 1 });
        assert_eq!(a.mat().sub(b.mat()).max_abs(), 0.0);
    }

    #[test]
    fn different_devices_differ() {
        let a = MeasuredUnitCell::fabricate(1).measure(F0, State { theta: 0, phi: 0 });
        let b = MeasuredUnitCell::fabricate(2).measure(F0, State { theta: 0, phi: 0 });
        assert!(a.mat().sub(b.mat()).max_abs() > 1e-4);
    }

    #[test]
    fn measured_magnitudes_not_above_theory_plus_noise() {
        // Paper: "maximum magnitudes from the simulation and measurement
        // results are lower than the theoretical value".
        let dev = MeasuredUnitCell::fabricate(3);
        for n in 0..6 {
            let st = State { theta: n, phi: 0 };
            let s = dev.measure(F0, st);
            let (i21, i31, ..) = ideal::s_params(deg(TABLE_I_DEG[n]), 0.0);
            assert!(s.s(1, 0).abs() <= i21.abs() + 0.02, "state {n} S21");
            assert!(s.s(2, 0).abs() <= i31.abs() + 0.02, "state {n} S31");
        }
    }

    #[test]
    fn measured_tracks_theory_shape() {
        // Correlation between measured and ideal |S21| across θ states
        // should be strongly positive.
        let dev = MeasuredUnitCell::fabricate(4);
        let meas: Vec<f64> = (0..6)
            .map(|n| dev.measure(F0, State { theta: n, phi: 0 }).s(1, 0).abs())
            .collect();
        let ideal_m: Vec<f64> =
            TABLE_I_DEG.iter().map(|&d| ideal::s_params(deg(d), 0.0).0.abs()).collect();
        // both should be increasing overall
        assert!(meas[5] > meas[0]);
        let corr = pearson(&meas, &ideal_m);
        assert!(corr > 0.97, "corr = {corr}");
    }

    fn pearson(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len() as f64;
        let ma = a.iter().sum::<f64>() / n;
        let mb = b.iter().sum::<f64>() / n;
        let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum();
        let va: f64 = a.iter().map(|x| (x - ma) * (x - ma)).sum();
        let vb: f64 = b.iter().map(|y| (y - mb) * (y - mb)).sum();
        cov / (va * vb).sqrt()
    }

    #[test]
    fn sweep_produces_touchstone() {
        let dev = MeasuredUnitCell::fabricate(5);
        let ts = dev.sweep(State { theta: 0, phi: 0 }, 1.0e9, 3.0e9, 21);
        assert_eq!(ts.points.len(), 21);
        assert!((ts.points[0].0 - 1.0e9).abs() < 1.0);
        assert!((ts.points[20].0 - 3.0e9).abs() < 1.0);
        // Round-trips through the Touchstone text format.
        let text = ts.to_string_ri();
        let back = Touchstone::parse(&text, 4).unwrap();
        assert_eq!(back.points.len(), 21);
    }

    #[test]
    fn zero_spread_recovers_simulation() {
        let spread = FabSpread { len_err: 0.0, hybrid_err: 0.0, arm_err: 0.0, noise: 0.0 };
        let dev = MeasuredUnitCell::fabricate_with(9, spread);
        let sim = UnitCellCircuit::prototype().sparams(F0, State { theta: 3, phi: 3 });
        let meas = dev.measure(F0, State { theta: 3, phi: 3 });
        assert!(meas.mat().sub(sim.mat()).max_abs() < 1e-12);
    }
}
