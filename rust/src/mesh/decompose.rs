//! Rotation decomposition (eqs. 27–30) and SVD synthesis (eq. 31).
//!
//! Any N×N unitary `U` factors as `U = T_1·T_2⋯T_S·D^H` with
//! `S = N(N−1)/2`, where each `T_k` embeds one unit-cell matrix
//! `t(θ_k, φ_k)` (eq. 5) on an adjacent channel pair and `D` is a diagonal
//! phase layer. The factors are found by progressively nulling `U^H` with
//! right-multiplied cell matrices (the Reck procedure the paper cites
//! [45]). Signal-flow realization: input phases `D^H`, then cells
//! `T_S … T_1` in mesh order.

use super::topology::MeshTopology;
use crate::device::ideal::t_matrix;
use crate::math::c64::C64;
use crate::math::cmat::CMat;
use crate::math::svd::svd;
use std::f64::consts::PI;

/// One programmed unit cell: channel pair + continuous phases.
#[derive(Clone, Copy, Debug)]
pub struct CellSetting {
    /// Upper channel (cell crosses `p` and `q = p+1`).
    pub p: usize,
    pub q: usize,
    /// Internal phase θ (radians) — power-splitting control.
    pub theta: f64,
    /// Output phase φ (radians).
    pub phi: f64,
}

/// A fully programmed mesh: input phase layer + cells in signal-flow order.
#[derive(Clone, Debug)]
pub struct MeshProgram {
    pub n: usize,
    /// Input phase of channel `i`: the signal is multiplied by
    /// `e^{j·input_phases[i]}` before entering the mesh.
    pub input_phases: Vec<f64>,
    /// Cells in signal-flow order (matches `MeshTopology::reck(n)`).
    pub cells: Vec<CellSetting>,
}

impl MeshProgram {
    /// Apply the programmed mesh to a vector (ideal cells).
    pub fn apply(&self, x: &[C64]) -> Vec<C64> {
        assert_eq!(x.len(), self.n);
        let mut y: Vec<C64> = x
            .iter()
            .zip(&self.input_phases)
            .map(|(&v, &ph)| v * C64::cis(ph))
            .collect();
        for c in &self.cells {
            let t = t_matrix(c.theta, c.phi);
            let (yp, yq) = (y[c.p], y[c.q]);
            y[c.p] = t[(0, 0)] * yp + t[(0, 1)] * yq;
            y[c.q] = t[(1, 0)] * yp + t[(1, 1)] * yq;
        }
        y
    }

    /// Compose the full N×N transfer matrix (ideal cells).
    pub fn matrix(&self) -> CMat {
        let mut m = CMat::diag(&self.input_phases.iter().map(|&p| C64::cis(p)).collect::<Vec<_>>());
        for c in &self.cells {
            let t = t_matrix(c.theta, c.phi);
            // m ← embed(t) · m, done row-wise (only rows p, q change).
            for j in 0..self.n {
                let mp = m[(c.p, j)];
                let mq = m[(c.q, j)];
                m[(c.p, j)] = t[(0, 0)] * mp + t[(0, 1)] * mq;
                m[(c.q, j)] = t[(1, 0)] * mp + t[(1, 1)] * mq;
            }
        }
        m
    }

    /// The topology this program assumes.
    pub fn topology(&self) -> MeshTopology {
        MeshTopology::reck(self.n)
    }
}

/// Decompose a unitary `u` into a [`MeshProgram`]. Panics if `u` is not
/// square; accuracy degrades gracefully if `u` is only approximately
/// unitary (the residual lands in the reconstruction error).
pub fn decompose_unitary(u: &CMat) -> MeshProgram {
    assert!(u.is_square(), "decompose_unitary needs a square matrix");
    let n = u.rows();
    let topo = MeshTopology::reck(n);
    let mut v = u.hermitian();

    // Nulling order (reverse signal flow): rows r = n-1 .. 1, cols c = 0 .. r-1.
    let mut null_cells: Vec<CellSetting> = Vec::with_capacity(topo.cells());
    for r in (1..n).rev() {
        for c in 0..r {
            let (theta, phi) = if v[(r, c)].abs() < 1e-14 {
                // Already null: park the cell in the bar state (θ = π keeps
                // the channels unmixed; t(π, 0) = diag(1, −1)).
                (PI, 0.0)
            } else {
                let z = -(v[(r, c + 1)] / v[(r, c)]);
                (2.0 * z.abs().atan(), -z.arg())
            };
            let cell = CellSetting { p: c, q: c + 1, theta, phi };
            // v ← v · embed(t): columns c, c+1 mix.
            let t = t_matrix(theta, phi);
            for row in 0..n {
                let a = v[(row, c)];
                let b = v[(row, c + 1)];
                v[(row, c)] = a * t[(0, 0)] + b * t[(1, 0)];
                v[(row, c + 1)] = a * t[(0, 1)] + b * t[(1, 1)];
            }
            debug_assert!(v[(r, c)].abs() < 1e-9, "null failed at ({r},{c}): {:?}", v[(r, c)]);
            null_cells.push(cell);
        }
    }

    // v is now diagonal D with unimodular entries; U = T_1⋯T_S·D^H, so the
    // input phase layer is D^H = conj(D).
    let input_phases: Vec<f64> = (0..n).map(|i| -v[(i, i)].arg()).collect();
    null_cells.reverse(); // signal-flow order
    MeshProgram { n, input_phases, cells: null_cells }
}

/// SVD synthesis of an arbitrary real or complex matrix (eq. 31):
/// `M = σ_max · U·diag(σ/σ_max)·V^H`. Returns the two mesh programs, the
/// normalized diagonal (all entries ≤ 1, realizable as attenuation), and
/// the global scale `σ_max` (absorbed digitally, or by distributing gain).
pub struct SvdSynthesis {
    pub u_mesh: MeshProgram,
    /// Normalized singular values (σ/σ_max), each in [0, 1].
    pub diag: Vec<f64>,
    pub vh_mesh: MeshProgram,
    /// Global scale factor σ_max.
    pub scale: f64,
}

impl SvdSynthesis {
    /// Apply `M·x` through the synthesized stack (ideal cells).
    pub fn apply(&self, x: &[C64]) -> Vec<C64> {
        let mut y = self.vh_mesh.apply(x);
        for (yi, &d) in y.iter_mut().zip(&self.diag) {
            *yi = *yi * d;
        }
        let mut z = self.u_mesh.apply(&y);
        for zi in z.iter_mut() {
            *zi = *zi * self.scale;
        }
        z
    }

    /// Compose the synthesized matrix.
    pub fn matrix(&self) -> CMat {
        let d = CMat::diag(&self.diag.iter().map(|&x| C64::real(x)).collect::<Vec<_>>());
        self.u_mesh
            .matrix()
            .matmul(&d)
            .matmul(&self.vh_mesh.matrix())
            .scale(C64::real(self.scale))
    }
}

/// Synthesize an arbitrary matrix via SVD (eq. 31).
pub fn synthesize_real(m: &CMat) -> SvdSynthesis {
    assert!(m.is_square(), "synthesis needs a square matrix (pad rectangular targets)");
    let f = svd(m);
    let scale = f.s.first().copied().unwrap_or(1.0).max(1e-300);
    SvdSynthesis {
        u_mesh: decompose_unitary(&f.u),
        diag: f.s.iter().map(|&s| s / scale).collect(),
        vh_mesh: decompose_unitary(&f.vh),
        scale,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Rng;

    /// Random unitary via QR-free trick: svd of random → U·Vh.
    fn rand_unitary(rng: &mut Rng, n: usize) -> CMat {
        let a = CMat::from_fn(n, n, |_, _| C64::new(rng.normal(), rng.normal()));
        let f = svd(&a);
        f.u.matmul(&f.vh)
    }

    #[test]
    fn reconstructs_random_unitaries() {
        let mut rng = Rng::new(31);
        for n in [2, 3, 4, 8] {
            let u = rand_unitary(&mut rng, n);
            let prog = decompose_unitary(&u);
            assert_eq!(prog.cells.len(), n * (n - 1) / 2);
            let err = prog.matrix().sub(&u).max_abs();
            assert!(err < 1e-9, "n={n}: reconstruction error {err}");
        }
    }

    #[test]
    fn program_matches_topology_order() {
        let mut rng = Rng::new(32);
        let u = rand_unitary(&mut rng, 5);
        let prog = decompose_unitary(&u);
        let topo = MeshTopology::reck(5);
        for (cell, pair) in prog.cells.iter().zip(topo.pairs()) {
            assert_eq!((cell.p, cell.q), pair);
        }
    }

    #[test]
    fn apply_agrees_with_matrix() {
        let mut rng = Rng::new(33);
        let u = rand_unitary(&mut rng, 4);
        let prog = decompose_unitary(&u);
        let x: Vec<C64> = (0..4).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let y1 = prog.apply(&x);
        let y2 = prog.matrix().matvec(&x);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((*a - *b).abs() < 1e-10);
        }
    }

    #[test]
    fn identity_decomposes_and_reconstructs() {
        let prog = decompose_unitary(&CMat::eye(4));
        assert!(prog.matrix().sub(&CMat::eye(4)).max_abs() < 1e-10);
    }

    #[test]
    fn permutation_matrix_decomposes() {
        // A hard case: full channel permutation (every cell must cross).
        let mut p = CMat::zeros(4, 4);
        for i in 0..4 {
            p[(i, 3 - i)] = C64::ONE;
        }
        let prog = decompose_unitary(&p);
        assert!(prog.matrix().sub(&p).max_abs() < 1e-10);
    }

    #[test]
    fn theta_within_physical_range() {
        // The nulling construction keeps θ ∈ [0, π] (the device's full
        // cross↔bar range).
        let mut rng = Rng::new(34);
        let u = rand_unitary(&mut rng, 8);
        for cell in &decompose_unitary(&u).cells {
            assert!((0.0..=PI + 1e-12).contains(&cell.theta), "θ = {}", cell.theta);
        }
    }

    #[test]
    fn svd_synthesis_reconstructs_arbitrary_real() {
        let mut rng = Rng::new(35);
        for n in [2, 4, 8] {
            let m = CMat::from_fn(n, n, |_, _| C64::real(rng.normal()));
            let syn = synthesize_real(&m);
            let err = syn.matrix().sub(&m).max_abs();
            assert!(err < 1e-8, "n={n}: err {err}");
            // Diagonal is normalized (physically realizable attenuation).
            assert!(syn.diag.iter().all(|&d| (0.0..=1.0 + 1e-12).contains(&d)));
            // And apply() agrees.
            let x: Vec<C64> = (0..n).map(|_| C64::real(rng.normal())).collect();
            let y1 = syn.apply(&x);
            let y2 = m.matvec(&x);
            for (a, b) in y1.iter().zip(&y2) {
                assert!((*a - *b).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn near_unitary_input_degrades_gracefully() {
        let mut rng = Rng::new(36);
        let u = rand_unitary(&mut rng, 4);
        // Perturb slightly off-unitary.
        let pert = CMat::from_fn(4, 4, |i, j| u[(i, j)] + C64::new(rng.normal(), rng.normal()) * 1e-4);
        let prog = decompose_unitary(&pert);
        let err = prog.matrix().sub(&pert).max_abs();
        assert!(err < 1e-2, "err {err}");
    }
}
