//! Rotation decomposition (eqs. 27–30) and SVD synthesis (eq. 31).
//!
//! Any N×N unitary `U` factors as `U = T_1·T_2⋯T_S·D^H` with
//! `S = N(N−1)/2`, where each `T_k` embeds one unit-cell matrix
//! `t(θ_k, φ_k)` (eq. 5) on an adjacent channel pair and `D` is a diagonal
//! phase layer. The factors are found by progressively nulling `U^H` with
//! right-multiplied cell matrices (the Reck procedure the paper cites
//! [45]). Signal-flow realization: input phases `D^H`, then cells
//! `T_S … T_1` in mesh order.

use super::topology::MeshTopology;
use crate::device::ideal::t_matrix;
use crate::math::c64::C64;
use crate::math::cmat::CMat;
use crate::math::svd::svd;
use crate::processor::{Fidelity, LinearProcessor, ReprogramCost};
use std::f64::consts::PI;
use std::sync::OnceLock;

/// One programmed unit cell: channel pair + continuous phases.
#[derive(Clone, Copy, Debug)]
pub struct CellSetting {
    /// Upper channel (cell crosses `p` and `q = p+1`).
    pub p: usize,
    pub q: usize,
    /// Internal phase θ (radians) — power-splitting control.
    pub theta: f64,
    /// Output phase φ (radians).
    pub phi: f64,
}

/// A fully programmed mesh: input phase layer + cells in signal-flow order.
///
/// Beyond the ad-hoc `apply`/`matrix` surface, a program is directly a
/// [`LinearProcessor`] (the composed matrix is cached lazily on first
/// trait access), so decomposition outputs can be served from a
/// [`crate::coordinator::service::ProcessorPool`] or used as compiler
/// tile backends without re-synthesis. Mutate `cells`/`input_phases`
/// only *before* the first trait-level `matrix()` call — the cache is
/// write-once.
#[derive(Clone, Debug)]
pub struct MeshProgram {
    pub n: usize,
    /// Input phase of channel `i`: the signal is multiplied by
    /// `e^{j·input_phases[i]}` before entering the mesh.
    pub input_phases: Vec<f64>,
    /// Cells in signal-flow order (matches `MeshTopology::reck(n)`).
    pub cells: Vec<CellSetting>,
    /// Lazily composed transfer matrix for the [`LinearProcessor`] view.
    composed: OnceLock<CMat>,
}

impl MeshProgram {
    /// Assemble a program from its parts.
    pub fn new(n: usize, input_phases: Vec<f64>, cells: Vec<CellSetting>) -> MeshProgram {
        assert_eq!(input_phases.len(), n, "one input phase per channel");
        MeshProgram { n, input_phases, cells, composed: OnceLock::new() }
    }

    /// Apply the programmed mesh to a vector (ideal cells).
    pub fn apply(&self, x: &[C64]) -> Vec<C64> {
        assert_eq!(x.len(), self.n);
        let mut y: Vec<C64> = x
            .iter()
            .zip(&self.input_phases)
            .map(|(&v, &ph)| v * C64::cis(ph))
            .collect();
        for c in &self.cells {
            let t = t_matrix(c.theta, c.phi);
            let (yp, yq) = (y[c.p], y[c.q]);
            y[c.p] = t[(0, 0)] * yp + t[(0, 1)] * yq;
            y[c.q] = t[(1, 0)] * yp + t[(1, 1)] * yq;
        }
        y
    }

    /// Compose the full N×N transfer matrix (ideal cells).
    pub fn matrix(&self) -> CMat {
        let mut m = CMat::diag(&self.input_phases.iter().map(|&p| C64::cis(p)).collect::<Vec<_>>());
        for c in &self.cells {
            let t = t_matrix(c.theta, c.phi);
            // m ← embed(t) · m, done row-wise (only rows p, q change).
            for j in 0..self.n {
                let mp = m[(c.p, j)];
                let mq = m[(c.q, j)];
                m[(c.p, j)] = t[(0, 0)] * mp + t[(0, 1)] * mq;
                m[(c.q, j)] = t[(1, 0)] * mp + t[(1, 1)] * mq;
            }
        }
        m
    }

    /// The topology this program assumes.
    pub fn topology(&self) -> MeshTopology {
        MeshTopology::reck(self.n)
    }
}

impl LinearProcessor for MeshProgram {
    fn dims(&self) -> (usize, usize) {
        (self.n, self.n)
    }

    fn fidelity(&self) -> Fidelity {
        // Continuous phases on ideal analytic cells.
        Fidelity::Ideal
    }

    fn reprogram_cost(&self) -> ReprogramCost {
        // θ/φ per cell are the programmable variables (continuous here;
        // quantization makes them the discrete Table-I states), and a
        // rewrite recomposes two N-entry rows per cell like the discrete
        // mesh (≈14 real flops per entry) plus the input phase layer.
        let n = self.n as u64;
        ReprogramCost {
            state_vars: 2 * self.cells.len(),
            recompose_flops: self.cells.len() as u64 * 2 * n * 14 + n * 6,
        }
    }

    fn matrix(&self) -> &CMat {
        self.composed.get_or_init(|| MeshProgram::matrix(self))
    }
}

/// Decompose a unitary `u` into a [`MeshProgram`]. Panics if `u` is not
/// square; accuracy degrades gracefully if `u` is only approximately
/// unitary (the residual lands in the reconstruction error).
pub fn decompose_unitary(u: &CMat) -> MeshProgram {
    assert!(u.is_square(), "decompose_unitary needs a square matrix");
    let n = u.rows();
    let topo = MeshTopology::reck(n);
    let mut v = u.hermitian();

    // Nulling order (reverse signal flow): rows r = n-1 .. 1, cols c = 0 .. r-1.
    let mut null_cells: Vec<CellSetting> = Vec::with_capacity(topo.cells());
    for r in (1..n).rev() {
        for c in 0..r {
            let (theta, phi) = if v[(r, c)].abs() < 1e-14 {
                // Already null: park the cell in the bar state (θ = π keeps
                // the channels unmixed; t(π, 0) = diag(1, −1)).
                (PI, 0.0)
            } else {
                let z = -(v[(r, c + 1)] / v[(r, c)]);
                (2.0 * z.abs().atan(), -z.arg())
            };
            let cell = CellSetting { p: c, q: c + 1, theta, phi };
            // v ← v · embed(t): columns c, c+1 mix.
            let t = t_matrix(theta, phi);
            for row in 0..n {
                let a = v[(row, c)];
                let b = v[(row, c + 1)];
                v[(row, c)] = a * t[(0, 0)] + b * t[(1, 0)];
                v[(row, c + 1)] = a * t[(0, 1)] + b * t[(1, 1)];
            }
            debug_assert!(v[(r, c)].abs() < 1e-9, "null failed at ({r},{c}): {:?}", v[(r, c)]);
            null_cells.push(cell);
        }
    }

    // v is now diagonal D with unimodular entries; U = T_1⋯T_S·D^H, so the
    // input phase layer is D^H = conj(D).
    let input_phases: Vec<f64> = (0..n).map(|i| -v[(i, i)].arg()).collect();
    null_cells.reverse(); // signal-flow order
    MeshProgram::new(n, input_phases, null_cells)
}

/// SVD synthesis of an arbitrary real or complex matrix (eq. 31):
/// `M = σ_max · U·diag(σ/σ_max)·V^H`. Returns the two mesh programs, the
/// normalized diagonal (all entries ≤ 1, realizable as attenuation), and
/// the global scale `σ_max` (absorbed digitally, or by distributing gain).
pub struct SvdSynthesis {
    pub u_mesh: MeshProgram,
    /// Normalized singular values (σ/σ_max), each in [0, 1].
    pub diag: Vec<f64>,
    pub vh_mesh: MeshProgram,
    /// Global scale factor σ_max.
    pub scale: f64,
    /// Lazily composed `σ_max·U·diag·V^H` for the [`LinearProcessor`] view.
    composed: OnceLock<CMat>,
}

impl SvdSynthesis {
    /// Assemble a synthesis from its parts (the plan-cache rebuild path —
    /// no SVD or decomposition is redone).
    pub fn new(
        u_mesh: MeshProgram,
        diag: Vec<f64>,
        vh_mesh: MeshProgram,
        scale: f64,
    ) -> SvdSynthesis {
        assert_eq!(u_mesh.n, vh_mesh.n, "U and V^H meshes must share the channel count");
        assert_eq!(diag.len(), u_mesh.n, "one singular value per channel");
        SvdSynthesis { u_mesh, diag, vh_mesh, scale, composed: OnceLock::new() }
    }

    /// Apply `M·x` through the synthesized stack (ideal cells).
    pub fn apply(&self, x: &[C64]) -> Vec<C64> {
        let mut y = self.vh_mesh.apply(x);
        for (yi, &d) in y.iter_mut().zip(&self.diag) {
            *yi = *yi * d;
        }
        let mut z = self.u_mesh.apply(&y);
        for zi in z.iter_mut() {
            *zi = *zi * self.scale;
        }
        z
    }

    /// Compose the synthesized matrix.
    pub fn matrix(&self) -> CMat {
        let d = CMat::diag(&self.diag.iter().map(|&x| C64::real(x)).collect::<Vec<_>>());
        self.u_mesh
            .matrix()
            .matmul(&d)
            .matmul(&self.vh_mesh.matrix())
            .scale(C64::real(self.scale))
    }
}

impl LinearProcessor for SvdSynthesis {
    fn dims(&self) -> (usize, usize) {
        (self.u_mesh.n, self.vh_mesh.n)
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::Ideal
    }

    fn reprogram_cost(&self) -> ReprogramCost {
        // Both meshes plus the attenuator diagonal, then the three-factor
        // recomposition (two n×n complex matmuls ≈ 8n³ real flops each).
        let u = LinearProcessor::reprogram_cost(&self.u_mesh);
        let v = LinearProcessor::reprogram_cost(&self.vh_mesh);
        let n = self.diag.len() as u64;
        ReprogramCost {
            state_vars: u.state_vars + v.state_vars + self.diag.len(),
            recompose_flops: u.recompose_flops + v.recompose_flops + 16 * n * n * n,
        }
    }

    fn matrix(&self) -> &CMat {
        self.composed.get_or_init(|| SvdSynthesis::matrix(self))
    }
}

/// Synthesize an arbitrary matrix via SVD (eq. 31).
pub fn synthesize_real(m: &CMat) -> SvdSynthesis {
    assert!(m.is_square(), "synthesis needs a square matrix (pad rectangular targets)");
    let f = svd(m);
    let scale = f.s.first().copied().unwrap_or(1.0).max(1e-300);
    SvdSynthesis::new(
        decompose_unitary(&f.u),
        f.s.iter().map(|&s| s / scale).collect(),
        decompose_unitary(&f.vh),
        scale,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Rng;

    /// Random unitary via QR-free trick: svd of random → U·Vh.
    fn rand_unitary(rng: &mut Rng, n: usize) -> CMat {
        let a = CMat::from_fn(n, n, |_, _| C64::new(rng.normal(), rng.normal()));
        let f = svd(&a);
        f.u.matmul(&f.vh)
    }

    #[test]
    fn reconstructs_random_unitaries() {
        let mut rng = Rng::new(31);
        for n in [2, 3, 4, 8] {
            let u = rand_unitary(&mut rng, n);
            let prog = decompose_unitary(&u);
            assert_eq!(prog.cells.len(), n * (n - 1) / 2);
            let err = prog.matrix().sub(&u).max_abs();
            assert!(err < 1e-9, "n={n}: reconstruction error {err}");
        }
    }

    #[test]
    fn program_matches_topology_order() {
        let mut rng = Rng::new(32);
        let u = rand_unitary(&mut rng, 5);
        let prog = decompose_unitary(&u);
        let topo = MeshTopology::reck(5);
        for (cell, pair) in prog.cells.iter().zip(topo.pairs()) {
            assert_eq!((cell.p, cell.q), pair);
        }
    }

    #[test]
    fn apply_agrees_with_matrix() {
        let mut rng = Rng::new(33);
        let u = rand_unitary(&mut rng, 4);
        let prog = decompose_unitary(&u);
        let x: Vec<C64> = (0..4).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let y1 = prog.apply(&x);
        let y2 = prog.matrix().matvec(&x);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((*a - *b).abs() < 1e-10);
        }
    }

    #[test]
    fn identity_decomposes_and_reconstructs() {
        let prog = decompose_unitary(&CMat::eye(4));
        assert!(prog.matrix().sub(&CMat::eye(4)).max_abs() < 1e-10);
    }

    #[test]
    fn permutation_matrix_decomposes() {
        // A hard case: full channel permutation (every cell must cross).
        let mut p = CMat::zeros(4, 4);
        for i in 0..4 {
            p[(i, 3 - i)] = C64::ONE;
        }
        let prog = decompose_unitary(&p);
        assert!(prog.matrix().sub(&p).max_abs() < 1e-10);
    }

    #[test]
    fn theta_within_physical_range() {
        // The nulling construction keeps θ ∈ [0, π] (the device's full
        // cross↔bar range).
        let mut rng = Rng::new(34);
        let u = rand_unitary(&mut rng, 8);
        for cell in &decompose_unitary(&u).cells {
            assert!((0.0..=PI + 1e-12).contains(&cell.theta), "θ = {}", cell.theta);
        }
    }

    #[test]
    fn svd_synthesis_reconstructs_arbitrary_real() {
        let mut rng = Rng::new(35);
        for n in [2, 4, 8] {
            let m = CMat::from_fn(n, n, |_, _| C64::real(rng.normal()));
            let syn = synthesize_real(&m);
            let err = syn.matrix().sub(&m).max_abs();
            assert!(err < 1e-8, "n={n}: err {err}");
            // Diagonal is normalized (physically realizable attenuation).
            assert!(syn.diag.iter().all(|&d| (0.0..=1.0 + 1e-12).contains(&d)));
            // And apply() agrees.
            let x: Vec<C64> = (0..n).map(|_| C64::real(rng.normal())).collect();
            let y1 = syn.apply(&x);
            let y2 = m.matvec(&x);
            for (a, b) in y1.iter().zip(&y2) {
                assert!((*a - *b).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn mesh_program_is_a_linear_processor() {
        use crate::processor::{Fidelity, LinearProcessor};
        let mut rng = Rng::new(41);
        let u = rand_unitary(&mut rng, 4);
        let prog = decompose_unitary(&u);
        let p: &dyn LinearProcessor = &prog;
        assert_eq!(p.dims(), (4, 4));
        assert_eq!(p.fidelity(), Fidelity::Ideal);
        assert_eq!(p.reprogram_cost().state_vars, 2 * prog.cells.len());
        // Trait-cached composition equals the inherent composition, and the
        // batched trait execution equals the stage-wise apply.
        assert!(LinearProcessor::matrix(&prog).sub(&prog.matrix()).max_abs() < 1e-15);
        let x = CMat::from_fn(4, 3, |i, j| C64::new(0.3 * i as f64 - j as f64, 0.1 * j as f64));
        let y = p.apply_batch(&x);
        for j in 0..3 {
            let want = prog.apply(&x.col(j));
            for i in 0..4 {
                assert!((y[(i, j)] - want[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn svd_synthesis_is_a_linear_processor() {
        use crate::processor::{Fidelity, LinearProcessor};
        let mut rng = Rng::new(42);
        let m = CMat::from_fn(5, 5, |_, _| C64::real(rng.normal()));
        let syn = synthesize_real(&m);
        let p: &dyn LinearProcessor = &syn;
        assert_eq!(p.dims(), (5, 5));
        assert_eq!(p.fidelity(), Fidelity::Ideal);
        assert!(p.reprogram_cost().state_vars >= 2 * syn.u_mesh.cells.len());
        assert!(LinearProcessor::matrix(&syn).sub(&m).max_abs() < 1e-8);
        // Rebuild from parts (the plan-cache hit path) — same realization.
        let rebuilt = SvdSynthesis::new(
            syn.u_mesh.clone(),
            syn.diag.clone(),
            syn.vh_mesh.clone(),
            syn.scale,
        );
        assert!(
            LinearProcessor::matrix(&rebuilt).sub(LinearProcessor::matrix(&syn)).max_abs() < 1e-12
        );
    }

    #[test]
    fn near_unitary_input_degrades_gracefully() {
        let mut rng = Rng::new(36);
        let u = rand_unitary(&mut rng, 4);
        // Perturb slightly off-unitary.
        let pert =
            CMat::from_fn(4, 4, |i, j| u[(i, j)] + C64::new(rng.normal(), rng.normal()) * 1e-4);
        let prog = decompose_unitary(&pert);
        let err = prog.matrix().sub(&pert).max_abs();
        assert!(err < 1e-2, "err {err}");
    }
}
