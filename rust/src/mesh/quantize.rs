//! Phase quantization — mapping continuous cell programs onto the
//! prototype's 36 discrete states (Table I).
//!
//! The paper's central hardware limitation: each phase shifter offers only
//! six fixed phases (29°…154°), so a synthesized mesh can only be realized
//! approximately. This module quantizes programs and quantifies the error
//! (the source of the analog network's accuracy gap in Fig. 15).

use super::decompose::{CellSetting, MeshProgram};
use super::propagate::{DiscreteMesh, MeshBackend};
use crate::device::ideal::t_matrix;
use crate::device::State;
use crate::math::c64::C64;
use crate::math::cmat::CMat;
use crate::math::deg;
use crate::math::wrap_angle;
use crate::microwave::phase_shifter::TABLE_I_DEG;
use crate::processor::{Fidelity, LinearProcessor, ReprogramCost};

/// Nearest discrete θ-path index for a continuous θ (radians), by absolute
/// phase distance. θ is first folded into [0, π] (the device's physical
/// splitting range — sin²(θ/2) is what matters).
pub fn nearest_theta_state(theta: f64) -> usize {
    let t = fold_theta(theta);
    TABLE_I_DEG
        .iter()
        .enumerate()
        .min_by(|a, b| {
            let da = (deg(*a.1) - t).abs();
            let db = (deg(*b.1) - t).abs();
            da.partial_cmp(&db).unwrap()
        })
        .map(|(i, _)| i)
        .unwrap()
}

/// Nearest discrete φ-path index for a continuous φ (radians), by wrapped
/// angular distance.
pub fn nearest_phi_state(phi: f64) -> usize {
    TABLE_I_DEG
        .iter()
        .enumerate()
        .min_by(|a, b| {
            let da = wrap_angle(deg(*a.1) - phi).abs();
            let db = wrap_angle(deg(*b.1) - phi).abs();
            da.partial_cmp(&db).unwrap()
        })
        .map(|(i, _)| i)
        .unwrap()
}

/// Fold θ into `[0, π]` preserving the splitting ratio `sin²(θ/2)`…
/// approximately: the map `θ → 2π − θ` flips the sign of the cross terms,
/// which the φ layer can partially absorb. We fold conservatively and let
/// the quantization-error metric report the damage.
fn fold_theta(theta: f64) -> f64 {
    let t = theta.rem_euclid(2.0 * std::f64::consts::PI);
    if t > std::f64::consts::PI {
        2.0 * std::f64::consts::PI - t
    } else {
        t
    }
}

/// Quantize one cell to a device [`State`].
pub fn quantize_cell(c: &CellSetting) -> State {
    State { theta: nearest_theta_state(c.theta), phi: nearest_phi_state(c.phi) }
}

/// The quantized program: per-cell discrete states plus an error report.
#[derive(Clone, Debug)]
pub struct QuantizedProgram {
    pub states: Vec<State>,
    /// Per-cell Frobenius error ‖t(θ,φ) − t(θ_q,φ_q)‖_F.
    pub cell_errors: Vec<f64>,
}

impl QuantizedProgram {
    /// Worst per-cell error.
    pub fn max_error(&self) -> f64 {
        self.cell_errors.iter().copied().fold(0.0, f64::max)
    }

    /// Mean per-cell error.
    pub fn mean_error(&self) -> f64 {
        if self.cell_errors.is_empty() {
            0.0
        } else {
            self.cell_errors.iter().sum::<f64>() / self.cell_errors.len() as f64
        }
    }
}

/// Quantize a whole mesh program against an explicit per-cell block
/// table — the calibration-aware ("nearest-measured") selection rule.
///
/// Instead of snapping θ and φ to the nearest Table-I phases
/// independently (which assumes every cell realizes the *ideal* `t(θ, φ)`
/// of its programmed state), choose for each cell the state whose
/// **realized** transfer block — as reported by `block(cell, state)`,
/// e.g. a virtual-VNA measurement of that specific fabricated device —
/// is nearest in Frobenius norm to the continuous cell target. With
/// ideal blocks this is a joint (θ, φ) refinement of
/// [`quantize_program`]; with measured blocks it absorbs each device's
/// fabrication error into the state choice. `cell_errors` reports
/// ‖block(cell, chosen) − t(θ, φ)‖_F, so per-cell it is never larger
/// than programming the same table with per-phase nearest selection.
pub fn quantize_program_with(
    prog: &MeshProgram,
    block: impl Fn(usize, State) -> CMat,
) -> QuantizedProgram {
    let mut states = Vec::with_capacity(prog.cells.len());
    let mut cell_errors = Vec::with_capacity(prog.cells.len());
    for (i, c) in prog.cells.iter().enumerate() {
        let t_cont = t_matrix(c.theta, c.phi);
        let mut best = State { theta: 0, phi: 0 };
        let mut best_err = f64::INFINITY;
        for st in State::all() {
            let err = block(i, st).sub(&t_cont).fro_norm();
            if err < best_err {
                best_err = err;
                best = st;
            }
        }
        states.push(best);
        cell_errors.push(best_err);
    }
    QuantizedProgram { states, cell_errors }
}

/// Quantize a whole mesh program onto Table-I states.
pub fn quantize_program(prog: &MeshProgram) -> QuantizedProgram {
    let mut states = Vec::with_capacity(prog.cells.len());
    let mut cell_errors = Vec::with_capacity(prog.cells.len());
    for c in &prog.cells {
        let st = quantize_cell(c);
        states.push(st);
        let t_cont = t_matrix(c.theta, c.phi);
        let t_disc = t_matrix(deg(TABLE_I_DEG[st.theta]), deg(TABLE_I_DEG[st.phi]));
        cell_errors.push(t_disc.sub(&t_cont).fro_norm());
    }
    QuantizedProgram { states, cell_errors }
}

/// The ideal cell matrix of a discrete state (Table I phases).
pub fn state_t_matrix(st: State) -> crate::math::cmat::CMat {
    t_matrix(deg(TABLE_I_DEG[st.theta]), deg(TABLE_I_DEG[st.phi]))
}

/// A mesh programmed to realize a target unitary through Table-I
/// quantization — the [`LinearProcessor`] backend with
/// [`Fidelity::Quantized`].
///
/// Construction decomposes the target (eqs. 27–30), snaps every cell to
/// its nearest discrete state, programs a [`DiscreteMesh`] with the
/// result, and caches the *full* realized matrix including the program's
/// input phase layer `D^H` (which the bare mesh cannot absorb). The
/// quantization-error report is kept alongside for accuracy accounting.
pub struct QuantizedMesh {
    mesh: DiscreteMesh,
    input_phases: Vec<f64>,
    /// `mesh.matrix() · diag(e^{jφ_i})` — the realized transfer matrix.
    cached: CMat,
    /// Per-cell quantization-error report from programming.
    pub report: QuantizedProgram,
}

impl QuantizedMesh {
    /// Program a quantized mesh realizing (approximately) the unitary `u`.
    pub fn program_unitary(u: &CMat, backend: MeshBackend) -> QuantizedMesh {
        let prog = crate::mesh::decompose::decompose_unitary(u);
        let report = quantize_program(&prog);
        let mut mesh = DiscreteMesh::new(u.rows(), backend);
        mesh.set_states(&report.states);
        let mut q = QuantizedMesh {
            mesh,
            input_phases: prog.input_phases,
            cached: CMat::eye(u.rows()),
            report,
        };
        q.recache();
        q
    }

    /// Rebuild a programmed mesh from saved parts — the compiler's
    /// plan-cache hit path: no decomposition or quantization is redone,
    /// only the (cheap) state programming and composition.
    pub fn from_parts(
        report: QuantizedProgram,
        input_phases: Vec<f64>,
        backend: MeshBackend,
    ) -> QuantizedMesh {
        let n = input_phases.len();
        let mut mesh = DiscreteMesh::new(n, backend);
        assert_eq!(report.states.len(), mesh.cells(), "one state per Reck cell");
        mesh.set_states(&report.states);
        let mut q = QuantizedMesh { mesh, input_phases, cached: CMat::eye(n), report };
        q.recache();
        q
    }

    fn recache(&mut self) {
        let phases: Vec<C64> = self.input_phases.iter().map(|&p| C64::cis(p)).collect();
        self.cached = LinearProcessor::matrix(&self.mesh).gemm(&CMat::diag(&phases));
    }

    /// The underlying discrete mesh (read-only: the cached composition
    /// includes the input phase layer).
    pub fn mesh(&self) -> &DiscreteMesh {
        &self.mesh
    }

    /// The program's input phase layer `D^H` (one phase per channel).
    pub fn input_phases(&self) -> &[f64] {
        &self.input_phases
    }
}

impl LinearProcessor for QuantizedMesh {
    fn dims(&self) -> (usize, usize) {
        LinearProcessor::dims(&self.mesh)
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::Quantized
    }

    fn reprogram_cost(&self) -> ReprogramCost {
        self.mesh.reprogram_cost()
    }

    fn matrix(&self) -> &CMat {
        &self.cached
    }

    fn state_code(&self) -> Option<Vec<usize>> {
        self.mesh.state_code()
    }

    fn set_state_code(&mut self, code: &[usize]) -> bool {
        self.mesh.set_encoded(code);
        self.recache();
        true
    }

    fn as_mesh(&self) -> Option<&DiscreteMesh> {
        Some(&self.mesh)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::f64::consts::PI;

    #[test]
    fn exact_table_phases_map_to_themselves() {
        for (i, &d) in TABLE_I_DEG.iter().enumerate() {
            assert_eq!(nearest_theta_state(deg(d)), i);
            assert_eq!(nearest_phi_state(deg(d)), i);
        }
    }

    #[test]
    fn midpoints_pick_nearer_neighbor() {
        // 29° and 53°: 40° is closer to 29°? |40-29|=11 < |40-53|=13 → L1.
        assert_eq!(nearest_theta_state(deg(40.0)), 0);
        assert_eq!(nearest_theta_state(deg(42.0)), 1);
    }

    #[test]
    fn theta_folding() {
        // 2π − 29° folds to 29°.
        assert_eq!(nearest_theta_state(2.0 * PI - deg(29.0)), 0);
        // θ slightly above π folds below π.
        assert_eq!(nearest_theta_state(PI + 0.1), nearest_theta_state(PI - 0.1));
    }

    #[test]
    fn phi_wraps() {
        // φ = −206° ≡ 154°.
        assert_eq!(nearest_phi_state(deg(-206.0)), 5);
    }

    #[test]
    fn quantize_program_reports_errors() {
        use crate::math::cmat::CMat;
        use crate::math::rng::Rng;
        use crate::math::svd::svd;
        let mut rng = Rng::new(77);
        let a = CMat::from_fn(4, 4, |_, _| crate::math::c64::C64::new(rng.normal(), rng.normal()));
        let f = svd(&a);
        let u = f.u.matmul(&f.vh);
        let prog = super::super::decompose::decompose_unitary(&u);
        let q = quantize_program(&prog);
        assert_eq!(q.states.len(), prog.cells.len());
        // Errors are bounded: ‖t1 − t2‖_F ≤ 2√2 for unitary 2×2s… and
        // nonzero in general for random targets.
        assert!(q.max_error() <= 2.0 * (2.0f64).sqrt() + 1e-9);
        assert!(q.mean_error() > 0.0);
    }

    #[test]
    fn joint_block_selection_never_increases_cell_error() {
        // With IDEAL blocks, `quantize_program_with` minimizes exactly the
        // metric `quantize_program` *reports* (‖t_disc − t_cont‖_F), so its
        // per-cell errors are a lower bound by construction.
        use crate::math::cmat::CMat;
        use crate::math::rng::Rng;
        use crate::math::svd::svd;
        let mut rng = Rng::new(0xCA1);
        let a = CMat::from_fn(5, 5, |_, _| crate::math::c64::C64::new(rng.normal(), rng.normal()));
        let f = svd(&a);
        let u = f.u.matmul(&f.vh);
        let prog = super::super::decompose::decompose_unitary(&u);
        let snap = quantize_program(&prog);
        let joint = quantize_program_with(&prog, |_, st| state_t_matrix(st));
        assert_eq!(joint.states.len(), snap.states.len());
        for (j, s) in joint.cell_errors.iter().zip(&snap.cell_errors) {
            assert!(*j <= *s + 1e-12, "joint {j} > snap {s}");
        }
    }

    #[test]
    fn state_t_matrix_is_unitary() {
        for st in State::all() {
            assert!(state_t_matrix(st).is_unitary(1e-12));
        }
    }

    #[test]
    fn quantized_mesh_approximates_target_unitary() {
        use crate::math::rng::Rng;
        use crate::math::svd::svd;
        let mut rng = Rng::new(0x9A);
        let a = CMat::from_fn(4, 4, |_, _| C64::new(rng.normal(), rng.normal()));
        let f = svd(&a);
        let u = f.u.matmul(&f.vh);
        let q = QuantizedMesh::program_unitary(&u, MeshBackend::Ideal);
        assert_eq!(LinearProcessor::fidelity(&q), Fidelity::Quantized);
        assert_eq!(LinearProcessor::dims(&q), (4, 4));
        // 36 states per cell → coarse, but the realized matrix must
        // correlate with the target far better than chance, and must be
        // exactly unitary on the ideal backend.
        assert!(LinearProcessor::matrix(&q).is_unitary(1e-9));
        // Two independent random unitaries sit at relative distance ≈ √2;
        // the quantized realization must land meaningfully closer.
        let err = LinearProcessor::matrix(&q).sub(&u).fro_norm() / u.fro_norm();
        assert!(err < 1.2, "relative error {err}");
        assert!(q.report.mean_error() > 0.0);
    }

    #[test]
    fn from_parts_rebuilds_identically() {
        use crate::math::rng::Rng;
        use crate::math::svd::svd;
        let mut rng = Rng::new(0x9C);
        let a = CMat::from_fn(4, 4, |_, _| C64::new(rng.normal(), rng.normal()));
        let f = svd(&a);
        let u = f.u.matmul(&f.vh);
        let q = QuantizedMesh::program_unitary(&u, MeshBackend::Ideal);
        let rebuilt = QuantizedMesh::from_parts(
            q.report.clone(),
            q.input_phases().to_vec(),
            MeshBackend::Ideal,
        );
        assert!(
            LinearProcessor::matrix(&rebuilt).sub(LinearProcessor::matrix(&q)).max_abs() < 1e-15
        );
        assert_eq!(rebuilt.state_code(), q.state_code());
    }

    #[test]
    fn quantized_mesh_batch_matches_matvec() {
        use crate::math::rng::Rng;
        use crate::math::svd::svd;
        let mut rng = Rng::new(0x9B);
        let a = CMat::from_fn(3, 3, |_, _| C64::new(rng.normal(), rng.normal()));
        let f = svd(&a);
        let u = f.u.matmul(&f.vh);
        let q = QuantizedMesh::program_unitary(&u, MeshBackend::Ideal);
        let x = CMat::from_fn(3, 9, |i, j| C64::new(i as f64 - j as f64, 0.2 * j as f64));
        let y = q.apply_batch(&x);
        for j in 0..9 {
            let want = LinearProcessor::matrix(&q).matvec(&x.col(j));
            for i in 0..3 {
                assert!((y[(i, j)] - want[i]).abs() < 1e-12);
            }
        }
    }
}
