//! Mesh topology: the Reck-triangle cell arrangement of Fig. 13.

/// The fixed wiring of an N-channel mesh: an ordered list of unit cells,
/// each crossing an adjacent channel pair `(p, p+1)`, in **signal-flow
/// order** (the order a wavefront encounters them).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MeshTopology {
    n: usize,
    /// Channel pairs in signal-flow order.
    pairs: Vec<(usize, usize)>,
}

impl MeshTopology {
    /// The Reck (triangular) arrangement used by the paper's decomposition
    /// (eq. 28): `N(N−1)/2` cells. Signal-flow order is the reverse of the
    /// nulling order used in [`super::decompose`].
    pub fn reck(n: usize) -> Self {
        assert!(n >= 2, "mesh needs at least 2 channels");
        // Nulling order: rows r = n-1 .. 1, columns c = 0 .. r-1, channel
        // pair (c, c+1). Signal flow reverses it.
        let mut pairs = Vec::with_capacity(n * (n - 1) / 2);
        for r in (1..n).rev() {
            for c in 0..r {
                pairs.push((c, c + 1));
            }
        }
        pairs.reverse();
        MeshTopology { n, pairs }
    }

    /// Number of channels.
    pub fn channels(&self) -> usize {
        self.n
    }

    /// Number of unit cells — `N(N−1)/2` for the Reck mesh (28 for N = 8,
    /// matching the paper's "28 RFNN devices").
    pub fn cells(&self) -> usize {
        self.pairs.len()
    }

    /// Channel pair of cell `i` (signal-flow order).
    pub fn pair(&self, i: usize) -> (usize, usize) {
        self.pairs[i]
    }

    /// Iterate channel pairs in signal-flow order.
    pub fn pairs(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.pairs.iter().copied()
    }

    /// Group cells into physical columns (Fig. 13): a cell goes into the
    /// earliest column in which its channels are not already used by a
    /// previous (signal-flow) cell of the same or a later column.
    /// Returns, per column, the cell indices it contains.
    pub fn columns(&self) -> Vec<Vec<usize>> {
        let mut col_of_channel = vec![0usize; self.n]; // next free column per channel
        let mut columns: Vec<Vec<usize>> = Vec::new();
        for (i, &(p, q)) in self.pairs.iter().enumerate() {
            let col = col_of_channel[p].max(col_of_channel[q]);
            if columns.len() <= col {
                columns.resize_with(col + 1, Vec::new);
            }
            columns[col].push(i);
            col_of_channel[p] = col + 1;
            col_of_channel[q] = col + 1;
        }
        columns
    }

    /// Longest signal path in cells (mesh depth = number of columns); sets
    /// the latency estimate in Table II.
    pub fn depth(&self) -> usize {
        self.columns().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_count_is_n_choose_2() {
        for n in 2..=10 {
            let t = MeshTopology::reck(n);
            assert_eq!(t.cells(), n * (n - 1) / 2);
        }
    }

    #[test]
    fn paper_sizes() {
        // §IV-B: 8×8 processor from 28 devices; Fig. 13: 4×4 from 6.
        assert_eq!(MeshTopology::reck(8).cells(), 28);
        assert_eq!(MeshTopology::reck(4).cells(), 6);
    }

    #[test]
    fn pairs_are_adjacent_and_in_range() {
        let t = MeshTopology::reck(6);
        for (p, q) in t.pairs() {
            assert_eq!(q, p + 1);
            assert!(q < 6);
        }
    }

    #[test]
    fn columns_partition_cells_without_channel_conflicts() {
        let t = MeshTopology::reck(8);
        let cols = t.columns();
        let total: usize = cols.iter().map(|c| c.len()).sum();
        assert_eq!(total, t.cells());
        for col in &cols {
            let mut used = vec![false; 8];
            for &i in col {
                let (p, q) = t.pair(i);
                assert!(!used[p] && !used[q], "channel conflict in column");
                used[p] = true;
                used[q] = true;
            }
        }
    }

    #[test]
    fn depth_reasonable() {
        // Reck mesh depth is 2N−3.
        for n in 2..=8 {
            let d = MeshTopology::reck(n).depth();
            assert_eq!(d, if n == 2 { 1 } else { 2 * n - 3 }, "n={n}");
        }
    }
}
