//! N×N linear RF analog processor built from 2×2 unit cells (paper §IV-B).
//!
//! * [`topology`] — the Reck-triangle arrangement of Fig. 13: which cell
//!   crosses which adjacent channel pair, in signal-flow order, and the
//!   physical column grouping.
//! * [`decompose`] — rotation decomposition (eqs. 27–30): factor an
//!   arbitrary N×N unitary into `N(N−1)/2` device matrices plus a diagonal
//!   phase layer, and SVD synthesis of arbitrary real matrices (eq. 31).
//! * [`quantize`] — map continuous cell phases onto the 36 discrete states
//!   of the prototype (Table I), the paper's main precision limit.
//! * [`propagate`] — forward simulation of a programmed mesh, either with
//!   ideal analytic cells or with per-cell *measured* (virtual-VNA) unit
//!   cells — how the 8×8 processor of the MNIST RFNN is "constructed based
//!   on the measured S-parameters of the unit cell".

pub mod decompose;
pub mod tensor_train;
pub mod propagate;
pub mod quantize;
pub mod topology;

pub use decompose::{decompose_unitary, synthesize_real, CellSetting, MeshProgram};
pub use propagate::{DiscreteMesh, MeshBackend};
pub use quantize::QuantizedMesh;
pub use topology::MeshTopology;
