//! Discrete-mesh forward simulation — the paper's 8×8 processor
//! "constructed based on the measured S-parameters of the unit cell".
//!
//! A [`DiscreteMesh`] is a fixed Reck topology where every cell is one
//! physical 2×2 device in one of its 36 states. The backend selects the
//! fidelity: ideal analytic cells at Table-I phases, or per-cell
//! virtual-VNA *measured* transfer blocks (each cell a distinct fabricated
//! device with its own imperfections — as on a real board of 28 unit
//! cells). The composed N×N matrix is cached and incrementally rebuilt on
//! state changes, because DSPSA training toggles states every minibatch.

use super::quantize::state_t_matrix;
use super::topology::MeshTopology;
use crate::device::vna::MeasuredUnitCell;
use crate::device::State;
use crate::math::c64::C64;
use crate::math::cmat::CMat;
use crate::processor::{Fidelity, LinearProcessor, ReprogramCost};

/// Cell fidelity backend.
#[derive(Clone)]
pub enum MeshBackend {
    /// Ideal analytic `t(θ, φ)` at the discrete Table-I phases.
    Ideal,
    /// Measured (virtual-VNA) transfer blocks; one fabricated device per
    /// cell, seeds derived from `base_seed`.
    Measured { base_seed: u64 },
}

/// An N-channel mesh of discrete-state unit cells.
pub struct DiscreteMesh {
    topo: MeshTopology,
    backend: MeshBackend,
    /// Per-cell measured devices (empty for the ideal backend).
    devices: Vec<MeasuredUnitCell>,
    /// Per-cell 6×6 lookup of transfer blocks (precomputed: state changes
    /// are frequent during training, measurement is deterministic).
    blocks: Vec<Vec<CMat>>,
    states: Vec<State>,
    /// Cells whose bias lines are broken: state changes are ignored
    /// (failure-injection ablation A5).
    stuck: usize,
    cached: CMat,
}

impl DiscreteMesh {
    /// Build a mesh with all cells in state `L1L1`.
    pub fn new(n: usize, backend: MeshBackend) -> Self {
        let topo = MeshTopology::reck(n);
        let cells = topo.cells();
        let devices: Vec<MeasuredUnitCell> = match &backend {
            MeshBackend::Ideal => Vec::new(),
            MeshBackend::Measured { base_seed } => {
                (0..cells)
                    .map(|i| MeasuredUnitCell::fabricate(base_seed.wrapping_add(i as u64)))
                    .collect()
            }
        };
        // Precompute all 36 blocks per cell.
        let blocks: Vec<Vec<CMat>> = (0..cells)
            .map(|i| {
                State::all()
                    .map(|st| match &backend {
                        MeshBackend::Ideal => state_t_matrix(st),
                        MeshBackend::Measured { .. } => devices[i].t_block(st),
                    })
                    .collect()
            })
            .collect();
        let states = vec![State { theta: 0, phi: 0 }; cells];
        let mut mesh =
            DiscreteMesh { topo, backend, devices, blocks, states, stuck: 0, cached: CMat::eye(n) };
        mesh.recompose();
        mesh
    }

    /// Replace every cell's 36-state transfer-block table (custom device
    /// populations for ablation studies, e.g. non-default fab spread).
    pub fn replace_blocks(&mut self, f: impl Fn(usize, State) -> CMat) {
        for i in 0..self.cells() {
            self.blocks[i] = State::all().map(|st| f(i, st)).collect();
        }
        self.recompose();
    }

    /// Mark the first `k` cells as stuck at their current state (dead
    /// switch-bias lines — failure injection). Subsequent state writes to
    /// those cells are ignored.
    pub fn set_stuck(&mut self, k: usize) {
        self.stuck = k.min(self.cells());
    }

    /// Number of channels.
    pub fn channels(&self) -> usize {
        self.topo.channels()
    }

    /// Number of cells.
    pub fn cells(&self) -> usize {
        self.topo.cells()
    }

    /// Current per-cell states.
    pub fn states(&self) -> &[State] {
        &self.states
    }

    /// The topology.
    pub fn topology(&self) -> &MeshTopology {
        &self.topo
    }

    /// The backend.
    pub fn backend(&self) -> &MeshBackend {
        &self.backend
    }

    /// The physical device instance behind cell `i` (measured backend
    /// only) — exposed for ablation studies and failure injection.
    pub fn device(&self, i: usize) -> Option<&MeasuredUnitCell> {
        self.devices.get(i)
    }

    /// Transfer block of cell `i` in state `st` (from the lookup).
    fn block(&self, i: usize, st: State) -> &CMat {
        &self.blocks[i][st.theta * crate::microwave::phase_shifter::N_STATES + st.phi]
    }

    /// Set all cell states and recompose the cached matrix. Stuck cells
    /// keep their current state.
    pub fn set_states(&mut self, states: &[State]) {
        assert_eq!(states.len(), self.cells());
        for (i, &st) in states.iter().enumerate() {
            if i >= self.stuck {
                self.states[i] = st;
            }
        }
        self.recompose();
    }

    /// Set one cell's state and recompose (ignored for stuck cells).
    pub fn set_state(&mut self, cell: usize, st: State) {
        if cell >= self.stuck {
            self.states[cell] = st;
        }
        self.recompose();
    }

    /// Encode states as a flat integer vector (θ0, φ0, θ1, φ1, …) — the
    /// DSPSA optimization variable.
    pub fn encode_states(&self) -> Vec<usize> {
        self.states.iter().flat_map(|s| [s.theta, s.phi]).collect()
    }

    /// Decode a flat integer vector into states (inverse of
    /// [`Self::encode_states`]) and apply it.
    pub fn set_encoded(&mut self, code: &[usize]) {
        assert_eq!(code.len(), 2 * self.cells());
        for (i, ch) in code.chunks(2).enumerate() {
            if i >= self.stuck {
                self.states[i] = State { theta: ch[0], phi: ch[1] };
            }
        }
        self.recompose();
    }

    /// The composed N×N transfer matrix.
    pub fn matrix(&self) -> &CMat {
        &self.cached
    }

    fn recompose(&mut self) {
        let n = self.channels();
        let mut m = CMat::eye(n);
        for (i, (p, q)) in self.topo.pairs().enumerate() {
            let t = self.block(i, self.states[i]).clone();
            for j in 0..n {
                let mp = m[(p, j)];
                let mq = m[(q, j)];
                m[(p, j)] = t[(0, 0)] * mp + t[(0, 1)] * mq;
                m[(q, j)] = t[(1, 0)] * mp + t[(1, 1)] * mq;
            }
        }
        self.cached = m;
    }

    /// Forward-propagate a complex vector through the mesh — the batch-1
    /// special case of [`LinearProcessor::apply_batch`].
    pub fn apply(&self, x: &[C64]) -> Vec<C64> {
        self.cached.matvec(x)
    }

    /// Forward-propagate a whole batch (`x` is `N × B`, one vector per
    /// column) as one blocked GEMM against the cached composition.
    pub fn apply_batch(&self, x: &CMat) -> CMat {
        LinearProcessor::apply_batch(self, x)
    }

    /// Forward-propagate a real vector and detect output magnitudes — the
    /// hidden-layer path of the MNIST RFNN (abs activation, eq. 20).
    pub fn apply_abs(&self, x: &[f64]) -> Vec<f64> {
        let xc: Vec<C64> = x.iter().map(|&v| C64::real(v)).collect();
        self.apply(&xc).iter().map(|z| z.abs()).collect()
    }

    /// Export the six `(C, N)` coefficient planes `(ar, ai, br, bi, cr,
    /// ci)` consumed by the AOT-compiled mesh kernel (see
    /// `python/compile/kernels/mesh.py`): per column, a cell on channels
    /// `(p, p+1)` contributes `A[p]=t00, B[p]=t01, A[p+1]=t11, C[p+1]=t10`;
    /// untouched channels pass through with `A=1`.
    pub fn coeff_planes(&self) -> [Vec<f32>; 6] {
        let n = self.channels();
        let columns = self.topo.columns();
        let c_cols = columns.len();
        let mut planes: [Vec<f32>; 6] = [
            vec![0.0; c_cols * n], // ar
            vec![0.0; c_cols * n], // ai
            vec![0.0; c_cols * n], // br
            vec![0.0; c_cols * n], // bi
            vec![0.0; c_cols * n], // cr
            vec![0.0; c_cols * n], // ci
        ];
        for k in 0..c_cols {
            for ch in 0..n {
                planes[0][k * n + ch] = 1.0; // identity pass-through
            }
            for &cell in &columns[k] {
                let (p, q) = self.topo.pair(cell);
                let t = self.block(cell, self.states[cell]);
                planes[0][k * n + p] = t[(0, 0)].re as f32;
                planes[1][k * n + p] = t[(0, 0)].im as f32;
                planes[2][k * n + p] = t[(0, 1)].re as f32;
                planes[3][k * n + p] = t[(0, 1)].im as f32;
                planes[0][k * n + q] = t[(1, 1)].re as f32;
                planes[1][k * n + q] = t[(1, 1)].im as f32;
                planes[4][k * n + q] = t[(1, 0)].re as f32;
                planes[5][k * n + q] = t[(1, 0)].im as f32;
            }
        }
        planes
    }

    /// Number of kernel columns (`C` in the coefficient-plane shape).
    pub fn kernel_columns(&self) -> usize {
        self.topo.columns().len()
    }

    /// Mean insertion loss of the composed matrix in dB: how much power a
    /// uniformly-excited input loses end to end (0 dB for ideal unitary).
    pub fn mean_loss_db(&self) -> f64 {
        let n = self.channels();
        let gram = self.cached.hermitian().matmul(&self.cached);
        let avg_gain: f64 = (0..n).map(|i| gram[(i, i)].re).sum::<f64>() / n as f64;
        -10.0 * avg_gain.log10()
    }
}

impl LinearProcessor for DiscreteMesh {
    fn dims(&self) -> (usize, usize) {
        let n = self.channels();
        (n, n)
    }

    fn fidelity(&self) -> Fidelity {
        match self.backend {
            MeshBackend::Ideal => Fidelity::Ideal,
            MeshBackend::Measured { .. } => Fidelity::Measured,
        }
    }

    fn reprogram_cost(&self) -> ReprogramCost {
        // A full state write recomposes the cached matrix: every cell
        // rewrites two N-entry rows at 2 complex multiplies + 1 complex
        // add per entry (≈14 real flops).
        let n = self.channels() as u64;
        ReprogramCost {
            state_vars: 2 * self.cells(),
            recompose_flops: self.cells() as u64 * 2 * n * 14,
        }
    }

    fn matrix(&self) -> &CMat {
        &self.cached
    }

    fn state_code(&self) -> Option<Vec<usize>> {
        Some(self.encode_states())
    }

    fn set_state_code(&mut self, code: &[usize]) -> bool {
        self.set_encoded(code);
        true
    }

    fn as_mesh(&self) -> Option<&DiscreteMesh> {
        Some(self)
    }

    fn as_mesh_mut(&mut self) -> Option<&mut DiscreteMesh> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_mesh_is_unitary_for_any_states() {
        let mut mesh = DiscreteMesh::new(4, MeshBackend::Ideal);
        assert!(mesh.matrix().is_unitary(1e-10));
        let states: Vec<State> =
            (0..mesh.cells()).map(|i| State { theta: i % 6, phi: (i * 2) % 6 }).collect();
        mesh.set_states(&states);
        assert!(mesh.matrix().is_unitary(1e-10));
    }

    #[test]
    fn measured_mesh_is_lossy_but_close_in_shape() {
        let mut ideal = DiscreteMesh::new(4, MeshBackend::Ideal);
        let mut meas = DiscreteMesh::new(4, MeshBackend::Measured { base_seed: 100 });
        let states: Vec<State> =
            (0..ideal.cells()).map(|i| State { theta: (i * 3) % 6, phi: i % 6 }).collect();
        ideal.set_states(&states);
        meas.set_states(&states);
        let loss = meas.mean_loss_db();
        assert!(loss > 1.0, "measured mesh should be lossy ({loss} dB)");
        assert!(loss < 40.0, "but not dead ({loss} dB)");
        // Unitarity broken but matrix finite.
        assert!(meas.matrix().is_finite());
        assert!(!meas.matrix().is_unitary(1e-3));
    }

    #[test]
    fn set_state_matches_full_recompose() {
        let mut a = DiscreteMesh::new(5, MeshBackend::Ideal);
        let mut b = DiscreteMesh::new(5, MeshBackend::Ideal);
        let mut states = vec![State { theta: 0, phi: 0 }; a.cells()];
        states[3] = State { theta: 4, phi: 2 };
        a.set_states(&states);
        b.set_state(3, State { theta: 4, phi: 2 });
        assert!(a.matrix().sub(b.matrix()).max_abs() < 1e-14);
    }

    #[test]
    fn encode_decode_round_trip() {
        let mut mesh = DiscreteMesh::new(4, MeshBackend::Ideal);
        let states: Vec<State> =
            (0..mesh.cells()).map(|i| State { theta: (i * 5) % 6, phi: (i + 1) % 6 }).collect();
        mesh.set_states(&states);
        let code = mesh.encode_states();
        let mut other = DiscreteMesh::new(4, MeshBackend::Ideal);
        other.set_encoded(&code);
        assert_eq!(other.states(), mesh.states());
        assert!(other.matrix().sub(mesh.matrix()).max_abs() < 1e-14);
    }

    #[test]
    fn apply_matches_matrix() {
        let mesh = DiscreteMesh::new(6, MeshBackend::Measured { base_seed: 3 });
        let x: Vec<C64> = (0..6).map(|i| C64::new(i as f64 * 0.1, -0.05 * i as f64)).collect();
        let y1 = mesh.apply(&x);
        let y2 = mesh.matrix().matvec(&x);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((*a - *b).abs() < 1e-12);
        }
    }

    #[test]
    fn apply_abs_nonnegative_and_consistent() {
        let mesh = DiscreteMesh::new(8, MeshBackend::Ideal);
        let x = vec![0.5; 8];
        let y = mesh.apply_abs(&x);
        assert_eq!(y.len(), 8);
        assert!(y.iter().all(|&v| v >= 0.0));
        // Ideal unitary: power conserved → Σ|y|² = Σ|x|².
        let pin: f64 = x.iter().map(|v| v * v).sum();
        let pout: f64 = y.iter().map(|v| v * v).sum();
        assert!((pin - pout).abs() < 1e-9);
    }

    #[test]
    fn measured_mesh_deterministic_per_seed() {
        let a = DiscreteMesh::new(4, MeshBackend::Measured { base_seed: 9 });
        let b = DiscreteMesh::new(4, MeshBackend::Measured { base_seed: 9 });
        assert!(a.matrix().sub(b.matrix()).max_abs() == 0.0);
        let c = DiscreteMesh::new(4, MeshBackend::Measured { base_seed: 10 });
        assert!(a.matrix().sub(c.matrix()).max_abs() > 1e-6);
    }

    #[test]
    fn coeff_planes_reproduce_composed_matrix() {
        // Apply the roll-encoded column sweep (the kernel's algorithm) and
        // compare against the cached dense matrix.
        let mut mesh = DiscreteMesh::new(8, MeshBackend::Measured { base_seed: 55 });
        let states: Vec<State> =
            (0..mesh.cells()).map(|i| State { theta: (i * 2) % 6, phi: (i * 3) % 6 }).collect();
        mesh.set_states(&states);
        let n = 8;
        let planes = mesh.coeff_planes();
        let c_cols = mesh.kernel_columns();
        let x: Vec<C64> = (0..n).map(|i| C64::new(0.3 * i as f64 - 1.0, 0.1 * i as f64)).collect();
        let mut z = x.clone();
        for k in 0..c_cols {
            let at = |plane: usize, ch: usize| planes[plane][k * n + ch] as f64;
            let mut next = vec![C64::ZERO; n];
            for ch in 0..n {
                let a = C64::new(at(0, ch), at(1, ch));
                let b = C64::new(at(2, ch), at(3, ch));
                let c = C64::new(at(4, ch), at(5, ch));
                let up = z[(ch + 1) % n];
                let dn = z[(ch + n - 1) % n];
                next[ch] = a * z[ch] + b * up + c * dn;
            }
            z = next;
        }
        let want = mesh.apply(&x);
        for (got, want) in z.iter().zip(&want) {
            assert!((*got - *want).abs() < 1e-6, "{got:?} vs {want:?}");
        }
    }

    #[test]
    fn eight_by_eight_paper_configuration() {
        let mesh = DiscreteMesh::new(8, MeshBackend::Measured { base_seed: 2023 });
        assert_eq!(mesh.cells(), 28); // paper: 28 devices
        assert_eq!(mesh.channels(), 8);
        assert!(mesh.matrix().is_finite());
    }

    #[test]
    fn apply_batch_equals_per_vector_apply() {
        let mesh = DiscreteMesh::new(6, MeshBackend::Measured { base_seed: 77 });
        let x = CMat::from_fn(6, 17, |i, j| C64::new(0.1 * i as f64 - 0.3, 0.05 * j as f64));
        let y = mesh.apply_batch(&x);
        assert_eq!((y.rows(), y.cols()), (6, 17));
        for j in 0..17 {
            let want = mesh.apply(&x.col(j));
            for i in 0..6 {
                assert!((y[(i, j)] - want[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn linear_processor_metadata() {
        let mut ideal = DiscreteMesh::new(4, MeshBackend::Ideal);
        let meas = DiscreteMesh::new(4, MeshBackend::Measured { base_seed: 1 });
        assert_eq!(LinearProcessor::fidelity(&ideal), Fidelity::Ideal);
        assert_eq!(LinearProcessor::fidelity(&meas), Fidelity::Measured);
        assert_eq!(LinearProcessor::dims(&ideal), (4, 4));
        let cost = ideal.reprogram_cost();
        assert_eq!(cost.state_vars, 2 * ideal.cells());
        assert!(cost.recompose_flops > 0);
        // State programming round-trips through the trait surface.
        let code: Vec<usize> = (0..2 * ideal.cells()).map(|i| i % 6).collect();
        assert!(ideal.set_state_code(&code));
        assert_eq!(ideal.state_code().as_deref(), Some(&code[..]));
        assert!(ideal.as_mesh().is_some());
    }
}
