//! Tensor-train (TT) factorized synaptic interconnections — the paper's §V
//! scaling proposal (refs. [50], [51]): replace one huge N×N mesh with a
//! chain of small TT cores, each realizable as a modest analog processor,
//! "greatly reducing the number of processor devices with little precision
//! degradation".
//!
//! A weight matrix `W ∈ R^{M×N}` with `M = Π m_k`, `N = Π n_k` factors as
//! TT cores `G_k ∈ R^{r_{k-1} × (m_k·n_k) × r_k}`. The matvec contracts one
//! core at a time, so the analog substrate only ever multiplies by
//! `r_{k-1}·m_k × r_k·n_k` blocks — e.g. a 256×256 layer with 2 cores of
//! rank 8 needs 2 meshes of ≤128ch instead of one 256-channel mesh
//! (device count ∝ N(N−1)/2 per mesh makes this a large saving).

use crate::math::rng::Rng;
use crate::nn::tensor::Mat;

/// A TT-factorized linear operator for 2-core decompositions
/// `W[(i1,i2),(j1,j2)] = Σ_r G1[i1,j1,r] · G2[r,i2,j2]`.
#[derive(Clone, Debug)]
pub struct TT2 {
    /// Output mode sizes (m1, m2) with M = m1·m2.
    pub m: (usize, usize),
    /// Input mode sizes (n1, n2) with N = n1·n2.
    pub n: (usize, usize),
    /// TT rank r.
    pub rank: usize,
    /// Core 1: shape [m1, n1, r] flattened row-major.
    pub g1: Vec<f64>,
    /// Core 2: shape [r, m2, n2] flattened row-major.
    pub g2: Vec<f64>,
}

impl TT2 {
    /// Random TT operator (for training from scratch, as [51] does).
    pub fn random(m: (usize, usize), n: (usize, usize), rank: usize, rng: &mut Rng) -> TT2 {
        let s1 = (2.0 / (n.0 * rank) as f64).sqrt();
        let s2 = (2.0 / n.1 as f64).sqrt();
        TT2 {
            m,
            n,
            rank,
            g1: (0..m.0 * n.0 * rank).map(|_| rng.normal() * s1).collect(),
            g2: (0..rank * m.1 * n.1).map(|_| rng.normal() * s2).collect(),
        }
    }

    /// Number of parameters (vs `m1·m2·n1·n2` dense).
    pub fn params(&self) -> usize {
        self.g1.len() + self.g2.len()
    }

    /// Dense parameter count of the equivalent full matrix.
    pub fn dense_params(&self) -> usize {
        self.m.0 * self.m.1 * self.n.0 * self.n.1
    }

    /// Unit-cell count if each contraction is realized as an analog mesh:
    /// one `m1·r`-channel mesh + one `r·m2`-channel-ish mesh (square upper
    /// bound `c(c-1)/2` each, c = max(in, out) per stage).
    pub fn mesh_cells(&self) -> usize {
        let c1 = (self.m.0 * self.rank).max(self.n.0);
        let c2 = (self.rank * self.n.1).max(self.m.1 * self.rank);
        c1 * (c1 - 1) / 2 + c2 * (c2 - 1) / 2
    }

    /// Unit-cell count of the direct dense realization (two unitary meshes
    /// of max(M, N) channels via SVD).
    pub fn dense_mesh_cells(&self) -> usize {
        let c = (self.m.0 * self.m.1).max(self.n.0 * self.n.1);
        c * (c - 1) // U and V^H meshes
    }

    /// Reconstruct the dense matrix (for tests / error measurement).
    pub fn to_dense(&self) -> Mat {
        let (m1, m2) = self.m;
        let (n1, n2) = self.n;
        let r = self.rank;
        let mut w = Mat::zeros(m1 * m2, n1 * n2);
        for i1 in 0..m1 {
            for i2 in 0..m2 {
                for j1 in 0..n1 {
                    for j2 in 0..n2 {
                        let mut acc = 0.0;
                        for k in 0..r {
                            acc += self.g1[(i1 * n1 + j1) * r + k]
                                * self.g2[(k * m2 + i2) * n2 + j2];
                        }
                        w[(i1 * m2 + i2, j1 * n2 + j2)] = acc;
                    }
                }
            }
        }
        w
    }

    /// TT matvec without materializing the dense matrix:
    /// contract core 2 then core 1 (cost O(r·N + r·M·n1) vs O(M·N)).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let (m1, m2) = self.m;
        let (n1, n2) = self.n;
        let r = self.rank;
        assert_eq!(x.len(), n1 * n2);
        // t[k][i2][j1] = Σ_{j2} G2[k,i2,j2] · x[j1,j2]
        let mut t = vec![0.0; r * m2 * n1];
        for k in 0..r {
            for i2 in 0..m2 {
                for j1 in 0..n1 {
                    let mut acc = 0.0;
                    for j2 in 0..n2 {
                        acc += self.g2[(k * m2 + i2) * n2 + j2] * x[j1 * n2 + j2];
                    }
                    t[(k * m2 + i2) * n1 + j1] = acc;
                }
            }
        }
        // y[i1,i2] = Σ_{j1,k} G1[i1,j1,k] · t[k,i2,j1]
        let mut y = vec![0.0; m1 * m2];
        for i1 in 0..m1 {
            for i2 in 0..m2 {
                let mut acc = 0.0;
                for j1 in 0..n1 {
                    for k in 0..r {
                        acc += self.g1[(i1 * n1 + j1) * r + k] * t[(k * m2 + i2) * n1 + j1];
                    }
                }
                y[i1 * m2 + i2] = acc;
            }
        }
        y
    }

    /// TT-SVD style 2-core factorization of a dense matrix: reshape
    /// `W[M×N] → A[(m1·n1) × (m2·n2)]` and truncate its SVD at `rank`.
    /// Returns the TT2 and the relative Frobenius truncation error.
    pub fn factor(w: &Mat, m: (usize, usize), n: (usize, usize), rank: usize) -> (TT2, f64) {
        let (m1, m2) = m;
        let (n1, n2) = n;
        assert_eq!(w.rows(), m1 * m2);
        assert_eq!(w.cols(), n1 * n2);
        // Reshape: A[(i1,j1),(i2,j2)] = W[(i1,i2),(j1,j2)]
        let a = crate::math::cmat::CMat::from_fn(m1 * n1, m2 * n2, |rj, ck| {
            let (i1, j1) = (rj / n1, rj % n1);
            let (i2, j2) = (ck / n2, ck % n2);
            crate::math::c64::C64::real(w[(i1 * m2 + i2, j1 * n2 + j2)])
        });
        let f = crate::math::svd::svd(&a);
        let r = rank.min(f.s.len());
        let mut g1 = vec![0.0; m1 * n1 * r];
        let mut g2 = vec![0.0; r * m2 * n2];
        for k in 0..r {
            let sk = f.s[k].sqrt();
            for rj in 0..m1 * n1 {
                g1[rj * r + k] = f.u[(rj, k)].re * sk;
            }
            for ck in 0..m2 * n2 {
                let (i2, j2) = (ck / n2, ck % n2);
                g2[(k * m2 + i2) * n2 + j2] = f.vh[(k, ck)].re * sk;
            }
        }
        let err2: f64 = f.s[r..].iter().map(|s| s * s).sum();
        let total2: f64 = f.s.iter().map(|s| s * s).sum();
        let rel = if total2 > 0.0 { (err2 / total2).sqrt() } else { 0.0 };
        (TT2 { m, n, rank: r, g1, g2 }, rel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Rng::new(1);
        let tt = TT2::random((4, 4), (4, 4), 3, &mut rng);
        let w = tt.to_dense();
        let x: Vec<f64> = (0..16).map(|i| (i as f64 * 0.37).sin()).collect();
        let via_tt = tt.matvec(&x);
        let xm = Mat::from_rows(16, 1, &x);
        let direct = w.matmul(&xm);
        for i in 0..16 {
            assert!((via_tt[i] - direct[(i, 0)]).abs() < 1e-10);
        }
    }

    #[test]
    fn full_rank_factorization_is_exact() {
        let mut rng = Rng::new(2);
        let w = Mat::from_fn(16, 16, |_, _| rng.normal());
        // Max rank of the reshaped 16×16 unfolding is 16.
        let (tt, err) = TT2::factor(&w, (4, 4), (4, 4), 16);
        assert!(err < 1e-10, "rel err {err}");
        let back = tt.to_dense();
        assert!(w.zip(&back, |a, b| (a - b).abs()).max_abs() < 1e-8);
    }

    #[test]
    fn truncation_error_decreases_with_rank() {
        let mut rng = Rng::new(3);
        let w = Mat::from_fn(16, 16, |_, _| rng.normal());
        let errs: Vec<f64> =
            [1, 2, 4, 8, 16].iter().map(|&r| TT2::factor(&w, (4, 4), (4, 4), r).1).collect();
        for pair in errs.windows(2) {
            assert!(pair[1] <= pair[0] + 1e-12, "{errs:?}");
        }
    }

    #[test]
    fn low_rank_matrix_compresses_losslessly() {
        // Build a matrix whose (m1n1)×(m2n2) unfolding has rank 2.
        let mut rng = Rng::new(4);
        let u = Mat::from_fn(16, 2, |_, _| rng.normal());
        let v = Mat::from_fn(2, 16, |_, _| rng.normal());
        let a = u.matmul(&v); // rank-2 unfolding A[(i1,j1),(i2,j2)]
        // Fold A back into W layout.
        let mut w = Mat::zeros(16, 16);
        for rj in 0..16 {
            for ck in 0..16 {
                let (i1, j1) = (rj / 4, rj % 4);
                let (i2, j2) = (ck / 4, ck % 4);
                w[(i1 * 4 + i2, j1 * 4 + j2)] = a[(rj, ck)];
            }
        }
        let (tt, err) = TT2::factor(&w, (4, 4), (4, 4), 2);
        assert!(err < 1e-10, "rel err {err}");
        assert_eq!(tt.params(), 4 * 4 * 2 + 2 * 4 * 4);
    }

    #[test]
    fn parameter_and_device_savings() {
        // §V scaling claim: TT needs far fewer devices than a flat mesh.
        let mut rng = Rng::new(5);
        let tt = TT2::random((16, 16), (16, 16), 8, &mut rng);
        assert_eq!(tt.dense_params(), 65536);
        assert!(tt.params() < tt.dense_params() / 10, "params {}", tt.params());
        assert!(
            tt.mesh_cells() < tt.dense_mesh_cells() / 2,
            "cells {} vs dense {}",
            tt.mesh_cells(),
            tt.dense_mesh_cells()
        );
    }
}
