//! Minimal property-based testing harness (offline substitute for proptest).
//!
//! ```
//! use rfnn::testing::prop::{forall, Gen};
//!
//! forall("abs is non-negative", 200, |g| {
//!     let x = g.f64_in(-1e6, 1e6);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```
//!
//! Each case gets a deterministic child RNG derived from the suite seed and
//! the case index; a failing case panics with the property name, case index
//! and seed so it can be replayed exactly with [`replay`].

use crate::math::rng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Default suite seed. Override with env `RFNN_PROP_SEED` for soak runs.
fn suite_seed() -> u64 {
    std::env::var("RFNN_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x2F5EED)
}

/// Generator handle passed to each property case.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform bool.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 0
    }

    /// A vector of f64 drawn from `[lo, hi)`.
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Pick one of the provided items.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// Access the raw RNG (for domain-specific generators).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of the property `f`. Panics on first failure
/// with replay information.
pub fn forall(name: &str, cases: u64, f: impl Fn(&mut Gen)) {
    forall_seeded(name, suite_seed(), cases, f)
}

/// [`forall`] with an explicit suite seed.
pub fn forall_seeded(name: &str, seed: u64, cases: u64, f: impl Fn(&mut Gen)) {
    for case in 0..cases {
        let mut g = Gen { rng: case_rng(seed, case) };
        let result = catch_unwind(AssertUnwindSafe(|| f(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case}/{cases} (suite seed {seed:#x}).\n\
                 replay: rfnn::testing::prop::replay({seed:#x}, {case}, ...)\n\
                 cause: {msg}"
            );
        }
    }
}

/// Re-run exactly one case of a property (for debugging a reported failure).
pub fn replay(seed: u64, case: u64, mut f: impl FnMut(&mut Gen)) {
    let mut g = Gen { rng: case_rng(seed, case) };
    f(&mut g);
}

fn case_rng(seed: u64, case: u64) -> Rng {
    Rng::new(seed ^ case.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(17))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall_seeded("sum commutes", 1, 50, |g| {
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_case() {
        let r = std::panic::catch_unwind(|| {
            forall_seeded("always fails", 7, 10, |_g| {
                panic!("boom");
            });
        });
        let err = r.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("always fails"), "{msg}");
        assert!(msg.contains("case 0"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn replay_reproduces_case_stream() {
        let mut seen = Vec::new();
        forall_seeded("record", 3, 5, |g| {
            // record first draw of each case via thread local side effect
            CASE_DRAWS.with(|c| c.borrow_mut().push(g.f64_in(0.0, 1.0)));
        });
        CASE_DRAWS.with(|c| seen = c.borrow().clone());
        // Replay case 2 and compare its first draw.
        let mut replayed = 0.0;
        replay(3, 2, |g| replayed = g.f64_in(0.0, 1.0));
        assert_eq!(replayed, seen[2]);
    }

    thread_local! {
        static CASE_DRAWS: std::cell::RefCell<Vec<f64>> =
            const { std::cell::RefCell::new(Vec::new()) };
    }

    #[test]
    fn generators_in_bounds() {
        forall_seeded("bounds", 11, 100, |g| {
            let x = g.f64_in(2.0, 3.0);
            assert!((2.0..3.0).contains(&x));
            let n = g.usize_in(4, 6);
            assert!((4..=6).contains(&n));
            let v = g.vec_f64(5, -1.0, 1.0);
            assert_eq!(v.len(), 5);
            assert!(v.iter().all(|x| (-1.0..1.0).contains(x)));
        });
    }
}
