//! Property tests for the tiling compiler's execution contract
//! (`crate::compiler`), per the PR-3 spec:
//!
//! * **Digital tiles are exact.** For random `M×N` targets up to 64×64,
//!   every tile size T ∈ {2, 4, 8} and batch sizes {1, 8, 64} — including
//!   ragged (non-multiple-of-T) shapes — `VirtualProcessor::apply_batch`
//!   matches the dense `CMat::gemm` up to floating-point accumulation
//!   order (the tiled path sums partial products per tile-column, so
//!   agreement is ~1e-12-relative, not bit-exact; the assembled matrix
//!   itself IS bit-exact for digital tiles).
//! * **Quantized tiles stay inside the documented tolerance band.** The
//!   compile-time report `plan.fro_error = ‖assembled − target‖_F` bounds
//!   every output: ‖Y_tiled − Y_dense‖_F ≤ fro_error · ‖X‖_F (since
//!   ‖ΔM·X‖_F ≤ ‖ΔM‖_F·‖X‖₂ ≤ ‖ΔM‖_F·‖X‖_F), and execution against the
//!   *assembled* matrix is exact to fp precision.

use super::prop::{forall_seeded, Gen};
use crate::compiler::{Calibration, Compiler, PerturbMode, PlanSpec, VirtualProcessor};
use crate::math::c64::C64;
use crate::math::cmat::CMat;
use crate::nn::dspsa::DspsaConfig;
use crate::processor::{Fidelity, LinearProcessor};

const TILES: [usize; 3] = [2, 4, 8];
const BATCHES: [usize; 3] = [1, 8, 64];

fn gen_target(g: &mut Gen, rows: usize, cols: usize, complex: bool) -> CMat {
    CMat::from_fn(rows, cols, |_, _| {
        if complex {
            C64::new(g.f64_in(-2.0, 2.0), g.f64_in(-2.0, 2.0))
        } else {
            C64::real(g.f64_in(-2.0, 2.0))
        }
    })
}

fn gen_batch(g: &mut Gen, rows: usize, batch: usize) -> CMat {
    CMat::from_fn(rows, batch, |_, _| C64::new(g.f64_in(-2.0, 2.0), g.f64_in(-2.0, 2.0)))
}

/// The shared per-case contract: shape bookkeeping, execution-vs-assembled
/// exactness, and the fro_error output band against the dense target.
fn check_virtual(vp: &VirtualProcessor, target: &CMat, x: &CMat) {
    let (m, _) = vp.dims();
    let b = x.cols();
    let y = vp.apply_batch(x);
    assert_eq!((y.rows(), y.cols()), (m, b));
    assert!(y.is_finite());
    // Tiled execution ≡ one dense GEMM against the assembled matrix (fp
    // accumulation order only).
    let via_assembled = LinearProcessor::matrix(vp).gemm(x);
    let scale = 1.0 + via_assembled.max_abs();
    assert!(
        y.sub(&via_assembled).max_abs() < 1e-10 * scale,
        "tiled execution diverged from the assembled matrix"
    );
    // Documented band vs the dense logical target.
    let want = target.gemm(x);
    let err = y.sub(&want).fro_norm();
    let band = vp.plan().fro_error * x.fro_norm() + 1e-9 * scale;
    assert!(err <= band, "‖Y_tiled − Y_dense‖_F = {err} exceeds the band {band}");
    // Batch-1 path is the same tiled kernel.
    if b > 0 {
        let col = vp.apply(&x.col(0));
        for i in 0..m {
            assert!((col[i] - y[(i, 0)]).abs() < 1e-12 * scale);
        }
    }
}

#[test]
fn digital_virtual_matches_dense_gemm_exactly() {
    forall_seeded("virtual digital ≡ dense gemm", 0x711E, 25, |g| {
        let m = g.usize_in(1, 64);
        let n = g.usize_in(1, 64);
        let t = *g.choose(&TILES);
        let b = *g.choose(&BATCHES);
        let target = gen_target(g, m, n, true);
        let vp = VirtualProcessor::compile(&target, &PlanSpec::new(t, Fidelity::Digital))
            .expect("digital compile");
        // Digital tiles: the assembled matrix is a bit-exact copy and the
        // compile-time error report is exactly zero.
        assert_eq!(LinearProcessor::matrix(&vp), &target, "m={m} n={n} t={t}");
        assert_eq!(vp.plan().fro_error, 0.0);
        let x = gen_batch(g, n, b);
        check_virtual(&vp, &target, &x);
        // And directly against the dense kernel, at fp-order tolerance.
        let y = vp.apply_batch(&x);
        let want = target.gemm(&x);
        let scale = 1.0 + want.max_abs();
        assert!(y.sub(&want).max_abs() < 1e-10 * scale, "m={m} n={n} t={t} b={b}");
    });
}

#[test]
fn quantized_virtual_within_documented_band() {
    // Fewer cases: each quantized tile pays an SVD + two Reck
    // decompositions + two mesh compositions.
    forall_seeded("virtual quantized ≤ band", 0x7120, 8, |g| {
        let m = g.usize_in(2, 24);
        let n = g.usize_in(2, 24);
        let t = *g.choose(&TILES);
        let b = *g.choose(&BATCHES);
        let target = gen_target(g, m, n, false);
        let vp = VirtualProcessor::compile(&target, &PlanSpec::new(t, Fidelity::Quantized))
            .expect("quantized compile");
        assert_eq!(vp.fidelity(), Fidelity::Quantized);
        assert!(vp.plan().fro_error.is_finite());
        check_virtual(&vp, &target, &gen_batch(g, n, b));
    });
}

#[test]
fn quantized_virtual_full_64x64_on_8x8_tiles() {
    // The headline shape: a 64×64 layer on an 8×8 fleet (64 boards of 28
    // cells — the paper's processor as the unit of deployment).
    forall_seeded("virtual quantized 64×64", 0x7121, 1, |g| {
        let target = gen_target(g, 64, 64, false);
        let vp = VirtualProcessor::compile(&target, &PlanSpec::new(8, Fidelity::Quantized))
            .expect("quantized compile");
        assert_eq!(vp.plan().grid.grid(), (8, 8));
        // 64 tiles × 2 meshes × 28 cells × 2 shifters.
        assert_eq!(vp.state_code().unwrap().len(), 64 * 2 * 28 * 2);
        check_virtual(&vp, &target, &gen_batch(g, 64, 8));
    });
}

#[test]
fn ragged_shapes_cover_every_tile_size() {
    // Deterministic ragged/degenerate shapes through every tile size and
    // batch size — the edge-padding contract must hold exactly.
    forall_seeded("virtual ragged digital", 0x7122, 6, |g| {
        for &(m, n) in &[(1usize, 1usize), (3, 5), (9, 7), (1, 64), (64, 1), (17, 23)] {
            let t = *g.choose(&TILES);
            let b = *g.choose(&BATCHES);
            let target = gen_target(g, m, n, true);
            let vp = VirtualProcessor::compile(&target, &PlanSpec::new(t, Fidelity::Digital))
                .expect("digital compile");
            assert_eq!(LinearProcessor::matrix(&vp), &target, "({m},{n}) t={t}");
            check_virtual(&vp, &target, &gen_batch(g, n, b));
        }
    });
}

#[test]
fn ideal_virtual_reconstructs_to_numerical_precision() {
    forall_seeded("virtual ideal ≈ dense", 0x7123, 6, |g| {
        let m = g.usize_in(2, 16);
        let n = g.usize_in(2, 16);
        let t = *g.choose(&TILES);
        let target = gen_target(g, m, n, false);
        let vp = VirtualProcessor::compile(&target, &PlanSpec::new(t, Fidelity::Ideal))
            .expect("ideal compile");
        // Continuous-phase synthesis is exact to numerical precision.
        assert!(
            vp.plan().fro_error < 1e-6 * (1.0 + target.fro_norm()),
            "ideal fro_error {}",
            vp.plan().fro_error
        );
        check_virtual(&vp, &target, &gen_batch(g, n, *g.choose(&BATCHES)));
    });
}

/// PR-4 satellite: tiles in a column are independent GEMMs, so
/// `apply_batch` may fan them across a scoped worker pool — and because
/// the accumulation order is fixed and sequential, the parallel path must
/// be BIT-IDENTICAL to the sequential one (and therefore inside every
/// band the sequential path satisfies).
#[test]
fn parallel_tiled_execution_is_bit_identical_to_sequential() {
    forall_seeded("virtual parallel ≡ sequential", 0x7125, 10, |g| {
        let m = g.usize_in(4, 48);
        let n = g.usize_in(4, 48);
        let t = *g.choose(&TILES);
        let b = *g.choose(&BATCHES);
        let target = gen_target(g, m, n, true);
        let vp = VirtualProcessor::compile(&target, &PlanSpec::new(t, Fidelity::Digital))
            .expect("digital compile");
        let x = gen_batch(g, n, b);
        let seq = vp.apply_batch_seq(&x);
        for workers in [1, 2, 3, 7] {
            let par = vp.apply_batch_par(&x, workers);
            assert_eq!(par, seq, "m={m} n={n} t={t} b={b} workers={workers}");
        }
        // The public entry point (heuristic dispatch) takes one of the two
        // identical paths.
        assert_eq!(vp.apply_batch(&x), seq);
        // And the shared contract still holds end to end.
        check_virtual(&vp, &target, &x);
    });
}

/// The parallel case on a discrete fleet: 32×32 quantized on 4×4 tiles
/// (64 tiles, work 64·16·64 = 65536 ≥ the threshold) drives the public
/// `apply_batch` down the scoped-pool path on multi-core hosts —
/// equivalence must hold there too, not just on digital tiles. (The
/// 64×64-on-8×8 headline shape is pinned separately at sequential cost
/// in `quantized_virtual_full_64x64_on_8x8_tiles`.)
#[test]
fn parallel_path_on_quantized_fleet_matches_sequential() {
    forall_seeded("virtual parallel quantized", 0x7126, 1, |g| {
        let target = gen_target(g, 32, 32, false);
        let vp = VirtualProcessor::compile(&target, &PlanSpec::new(4, Fidelity::Quantized))
            .expect("quantized compile");
        let x = gen_batch(g, 32, 64);
        let seq = vp.apply_batch_seq(&x);
        assert_eq!(vp.apply_batch_par(&x, 4), seq);
        assert_eq!(vp.apply_batch(&x), seq);
        check_virtual(&vp, &target, &x);
    });
}

/// PR-6 satellite: par ≡ seq must survive the execution arena. Interleave
/// parallel and sequential dispatches of different batch shapes on the
/// same fleet, so every dispatch reuses arena buffers shaped (and dirtied)
/// by a DIFFERENT previous dispatch — results must stay bit-identical to
/// the cold-path reference throughout.
#[test]
fn arena_reuse_keeps_parallel_bit_identical_to_sequential() {
    forall_seeded("arena par ≡ seq", 0x7127, 4, |g| {
        let m = g.usize_in(9, 33);
        let n = g.usize_in(9, 33);
        let t = *g.choose(&TILES);
        let target = gen_target(g, m, n, true);
        let vp = VirtualProcessor::compile(&target, &PlanSpec::new(t, Fidelity::Digital))
            .expect("digital compile");
        let shapes: Vec<usize> = (0..6).map(|_| *g.choose(&BATCHES)).collect();
        let refs: Vec<(CMat, CMat)> = shapes
            .iter()
            .map(|&b| gen_batch(g, n, b))
            .map(|x| (vp.apply_batch_seq(&x), x))
            .collect();
        for (i, (seq, x)) in refs.iter().enumerate() {
            let par = vp.apply_batch_par(x, 1 + i % 4);
            assert_eq!(&par, seq, "m={m} n={n} t={t} dispatch {i}");
            assert_eq!(&vp.apply_batch_seq(x), seq, "warm seq, dispatch {i}");
        }
    });
}

/// PR-5 tentpole: calibration-aware (nearest-measured) lowering keeps
/// whichever candidate program predicts the smaller realized tile error,
/// and the prediction is bit-exact w.r.t. instantiation — so per tile it
/// can NEVER be worse than nearest-ideal snapping, across fabrication
/// seeds and every physical tile size. On tile-divisible shapes the plan
/// error is the root-sum-square of disjoint per-tile errors, so the
/// fleet-level `fro_error` report tightens too.
#[test]
fn calibrated_lowering_never_worse_than_nearest_ideal() {
    forall_seeded("calibrated ≤ nearest-ideal", 0x7127, 3, |g| {
        let fab = g.usize_in(0, 1 << 30) as u64;
        for &t in &TILES {
            let k = if t == 8 { 1 } else { g.usize_in(1, 2) };
            let n = t * k;
            let target = gen_target(g, n, n, false);
            let compiler = Compiler::new();
            let cal_spec = PlanSpec::new(t, Fidelity::Measured).with_seed(fab);
            let snap_spec = cal_spec.with_calibration(Calibration::NearestIdeal);
            let cal = compiler.compile(&target, &cal_spec).expect("measured compile");
            let snap = compiler.compile(&target, &snap_spec).expect("measured compile");
            for (i, (c, s)) in cal.tiles.iter().zip(&snap.tiles).enumerate() {
                assert!(
                    c.error <= s.error + 1e-12,
                    "tile {i}: calibrated {} > nearest-ideal {} (t={t} fab={fab})",
                    c.error,
                    s.error
                );
            }
            assert!(
                cal.fro_error <= snap.fro_error + 1e-9,
                "t={t} n={n} fab={fab}: {} > {}",
                cal.fro_error,
                snap.fro_error
            );
            // The calibrated fleet still executes inside its (tighter)
            // documented band.
            let x = gen_batch(g, n, 4);
            check_virtual(&VirtualProcessor::new(cal), &target, &x);
        }
    });
}

/// The acceptance pin: on the DEFAULT fabrication seed, calibration-aware
/// lowering reports *strictly* lower `fro_error` than nearest-ideal (the
/// `rfnn compile --fidelity measured` comparison is this computation).
#[test]
fn calibration_strictly_tightens_on_the_default_fab_seed() {
    forall_seeded("calibration strictly tightens", 0x7128, 1, |g| {
        let target = gen_target(g, 12, 12, false);
        let compiler = Compiler::new();
        let cal_spec = PlanSpec::new(4, Fidelity::Measured);
        let snap_spec = cal_spec.with_calibration(Calibration::NearestIdeal);
        let cal = compiler.compile(&target, &cal_spec).unwrap();
        let snap = compiler.compile(&target, &snap_spec).unwrap();
        assert!(
            cal.fro_error < snap.fro_error,
            "calibration did not tighten: {} vs {}",
            cal.fro_error,
            snap.fro_error
        );
        // At least one tile actually switched to nearest-measured states.
        assert!(cal.tiles.iter().any(|t| t.calibrated));
        assert!(snap.tiles.iter().all(|t| !t.calibrated));
    });
}

/// PR-5 tentpole, training half: within the SAME evaluation budget and
/// from the same lowering, block-coordinate DSPSA matches or beats the
/// monolithic flat-code loss on a fixed-seed 8×8 target. Both optimizers
/// track their best evaluated code, so neither can end above the shared
/// starting loss; the comparison takes the best of three fixed optimizer
/// seeds per mode (SPSA trajectories are stochastic — a single seed pair
/// can favor either mode by luck) with a 5%-of-initial noise margin.
#[test]
fn block_coordinate_dspsa_matches_or_beats_monolithic_within_budget() {
    forall_seeded("block ≤ monolithic", 0x7129, 1, |g| {
        let target = gen_target(g, 8, 8, false);
        let spec = PlanSpec::new(4, Fidelity::Quantized);
        let budget = 300;
        let cfg = DspsaConfig::default();
        let seeds = [0xB10Cu64, 0xB10C ^ 0x5EED, 0xB10C ^ 0xFACE];
        let best_of = |mode: PerturbMode| -> (f64, f64, usize) {
            let mut best = f64::INFINITY;
            let mut initial = 0.0;
            let mut evals = 0;
            for &seed in &seeds {
                let mut vp = VirtualProcessor::compile(&target, &spec).unwrap();
                let r = vp
                    .train_states(&target, mode, budget, cfg, seed)
                    .expect("quantized fleet has states");
                // Best-tracking: no run ends above the shared start.
                assert!(r.final_loss <= r.initial_loss + 1e-12, "{mode:?} seed {seed}");
                best = best.min(r.final_loss);
                initial = r.initial_loss;
                evals = r.evals;
            }
            (best, initial, evals)
        };
        let (mono, mono_init, mono_evals) = best_of(PerturbMode::Monolithic);
        let (blk, blk_init, blk_evals) = best_of(PerturbMode::BlockRoundRobin);
        assert_eq!(mono_evals, blk_evals, "same perturbation budget");
        assert_eq!(mono_init, blk_init, "same lowering, same starting loss");
        assert!(
            blk <= mono + 0.05 * mono_init + 1e-12,
            "block {blk} > monolithic {mono} (init {mono_init})"
        );
    });
}

#[test]
fn measured_virtual_executes_within_its_own_report() {
    // Measured tiles carry fabrication imperfections; the band contract
    // must still hold because it is defined against the *realized* fleet.
    forall_seeded("virtual measured ≤ band", 0x7124, 3, |g| {
        let n = g.usize_in(2, 6);
        let target = gen_target(g, n, n, false);
        let vp = VirtualProcessor::compile(&target, &PlanSpec::new(2, Fidelity::Measured))
            .expect("measured compile");
        assert_eq!(vp.fidelity(), Fidelity::Measured);
        check_virtual(&vp, &target, &gen_batch(g, n, 8));
    });
}
