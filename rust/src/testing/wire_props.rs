//! Wire-protocol contract: every [`Job`]/[`JobResult`] variant round-trips
//! through the versioned `util::json` form byte-for-value; v2 and v3
//! documents decode through the explicit compat shims under pinned
//! upgrade rules (the v4 poll-mode kinds are refused in both); unknown
//! versions, malformed documents, and broken framing are refused
//! without panicking — the schema the CLI, benches, and the TCP transport
//! all rely on.

use crate::compiler::{Calibration, ShardSpec};
use crate::coordinator::router::{Admin, AdminReply};
use crate::coordinator::service::{compat, Job, JobResult, WIRE_VERSION};
use crate::coordinator::transport::{read_frame, Request, Response};
use crate::math::c64::C64;
use crate::math::cmat::CMat;
use crate::processor::Fidelity;
use crate::testing::prop::{forall, Gen};
use crate::util::json::{parse, Json};

fn arb_processor(g: &mut Gen) -> String {
    (*g.choose(&["mnist8", "cls2x2", "mesh8", "θ-pool"])).to_string()
}

fn arb_cmat(g: &mut Gen) -> CMat {
    let rows = g.usize_in(1, 5);
    let cols = g.usize_in(0, 4);
    let data: Vec<C64> =
        (0..rows * cols).map(|_| C64::new(g.normal(), g.normal())).collect();
    CMat::from_rows(rows, cols, &data)
}

fn arb_fidelity(g: &mut Gen) -> Fidelity {
    *g.choose(&[Fidelity::Digital, Fidelity::Ideal, Fidelity::Quantized, Fidelity::Measured])
}

/// A geometrically consistent random [`ShardSpec`] — the decoder derives
/// the slice height from the global geometry, so the slice must match it
/// exactly. The fabrication seed stays below 2^53: it rides the wire as a
/// JSON number, whose integer range ends there.
fn arb_shard_spec(g: &mut Gen) -> ShardSpec {
    let tile = *g.choose(&[2usize, 3, 4]);
    let rows = g.usize_in(1, 12);
    let cols = g.usize_in(1, 6);
    let gr = (rows + tile - 1) / tile;
    let row_start = g.usize_in(0, gr - 1);
    let grid_rows = g.usize_in(1, gr - row_start);
    let out_start = row_start * tile;
    let slice_rows = rows.min((row_start + grid_rows) * tile) - out_start;
    let data: Vec<C64> =
        (0..slice_rows * cols).map(|_| C64::new(g.normal(), g.normal())).collect();
    ShardSpec {
        rows,
        cols,
        tile,
        fidelity: arb_fidelity(g),
        measured_seed: g.usize_in(0, 1 << 50) as u64,
        calibration: *g.choose(&[Calibration::NearestIdeal, Calibration::NearestMeasured]),
        row_start,
        grid_rows,
        target: CMat::from_rows(slice_rows, cols, &data),
    }
}

fn arb_job(g: &mut Gen) -> Job {
    let processor = arb_processor(g);
    match g.usize_in(0, 6) {
        0 => {
            let n = g.usize_in(0, 30);
            Job::Infer { processor, image: (0..n).map(|_| g.f64_in(0.0, 1.0) as f32).collect() }
        }
        1 => Job::Classify {
            processor,
            classifier: g.usize_in(0, 5),
            point: [g.f64_in(-30.0, 30.0), g.f64_in(-30.0, 30.0)],
        },
        2 => Job::RawApply { processor, x: arb_cmat(g) },
        3 => {
            let n = g.usize_in(0, 16);
            Job::Reprogram { processor, code: (0..n).map(|_| g.usize_in(0, 5)).collect() }
        }
        4 => Job::Compile {
            name: processor,
            target: arb_cmat(g),
            tile: *g.choose(&[2usize, 4, 8]),
            fidelity: arb_fidelity(g),
        },
        5 => Job::ShardCompile { name: processor, spec: arb_shard_spec(g) },
        _ => Job::Poll { ticket: g.usize_in(0, 1 << 50) as u64 },
    }
}

fn arb_result(g: &mut Gen) -> JobResult {
    match g.usize_in(0, 8) {
        0 => JobResult::Infer {
            probs: (0..10).map(|_| g.f64_in(0.0, 1.0) as f32).collect(),
            queued_us: g.usize_in(0, 1 << 40) as u64,
            service_us: g.usize_in(0, 1 << 40) as u64,
        },
        1 => JobResult::Classify { yhat: g.f64_in(0.0, 1.0), reconfigured: g.bool() },
        2 => JobResult::RawApply { y: arb_cmat(g) },
        3 => JobResult::Reprogrammed { version: g.usize_in(1, 1 << 30) as u64 },
        4 => JobResult::Compiled {
            name: arb_processor(g),
            version: 1,
            grid: (g.usize_in(1, 8) as u64, g.usize_in(1, 8) as u64),
            tile: *g.choose(&[2u64, 4, 8]),
            fidelity: arb_fidelity(g),
            state_vars: g.usize_in(0, 10_000) as u64,
            fro_error: g.f64_in(0.0, 10.0),
            cache_hit: g.bool(),
        },
        5 => JobResult::ShardCompiled {
            name: arb_processor(g),
            version: 1,
            out_row_start: g.usize_in(0, 1 << 20) as u64,
            out_rows: g.usize_in(1, 1 << 20) as u64,
            grid: (g.usize_in(1, 8) as u64, g.usize_in(1, 8) as u64),
            tile: *g.choose(&[2u64, 4, 8]),
            fidelity: arb_fidelity(g),
            state_vars: g.usize_in(0, 10_000) as u64,
            fro_error: g.f64_in(0.0, 10.0),
            cache_hit: g.bool(),
        },
        6 => JobResult::Submitted { ticket: g.usize_in(0, 1 << 50) as u64 },
        7 => JobResult::Pending { ticket: g.usize_in(0, 1 << 50) as u64 },
        _ => JobResult::Rejected { reason: "a \"quoted\" reason\nwith θ unicode".into() },
    }
}

#[test]
fn job_round_trips_every_variant() {
    forall("job wire round-trip", 200, |g| {
        let job = arb_job(g);
        let text = job.encode();
        let back = Job::decode(&text).expect("decode what we encoded");
        assert_eq!(back, job, "wire: {text}");
    });
}

#[test]
fn result_round_trips_every_variant() {
    forall("result wire round-trip", 200, |g| {
        let result = arb_result(g);
        let text = result.encode();
        let back = JobResult::decode(&text).expect("decode what we encoded");
        assert_eq!(back, result, "wire: {text}");
    });
}

/// A small fixed shard payload (shard 1 of a 5×4 target under 2×2 tiles:
/// tile-row 1 of 3, owning output rows 2..4).
fn fixed_shard_spec() -> ShardSpec {
    ShardSpec {
        rows: 5,
        cols: 4,
        tile: 2,
        fidelity: Fidelity::Measured,
        measured_seed: 7,
        calibration: Calibration::NearestMeasured,
        row_start: 1,
        grid_rows: 1,
        target: CMat::from_fn(2, 4, |i, j| C64::new(i as f64 + 0.5, j as f64 - 1.0)),
    }
}

/// Deterministic coverage of all seven job + nine result variants, in
/// case the random distribution above ever shifts.
#[test]
fn every_variant_covered_explicitly() {
    let jobs = vec![
        Job::Infer { processor: "m".into(), image: vec![0.25, 0.5] },
        Job::Poll { ticket: 99 },
        Job::Classify { processor: "c".into(), classifier: 3, point: [1.5, -2.25] },
        Job::RawApply {
            processor: "p".into(),
            x: CMat::from_fn(2, 3, |i, j| C64::new(i as f64, j as f64 - 0.5)),
        },
        Job::Reprogram { processor: "p".into(), code: vec![0, 5, 2, 3] },
        Job::Compile {
            name: "virt".into(),
            target: CMat::from_fn(3, 2, |i, j| C64::new(i as f64 - 1.0, j as f64)),
            tile: 2,
            fidelity: Fidelity::Quantized,
        },
        Job::ShardCompile { name: "net.s1".into(), spec: fixed_shard_spec() },
    ];
    for job in jobs {
        let back = Job::decode(&job.encode()).expect("round trip");
        assert_eq!(back, job);
        // The version tag is actually on the wire.
        let v = parse(&job.encode()).unwrap();
        assert_eq!(v.get("v").and_then(Json::as_f64), Some(WIRE_VERSION as f64));
    }
    let results = vec![
        JobResult::Infer { probs: vec![0.1; 10], queued_us: 7, service_us: 9 },
        JobResult::Classify { yhat: 0.75, reconfigured: true },
        JobResult::RawApply { y: CMat::eye(2) },
        JobResult::Reprogrammed { version: 42 },
        JobResult::Compiled {
            name: "virt".into(),
            version: 1,
            grid: (2, 1),
            tile: 2,
            fidelity: Fidelity::Quantized,
            state_vars: 16,
            fro_error: 0.125,
            cache_hit: true,
        },
        JobResult::ShardCompiled {
            name: "net.s1".into(),
            version: 1,
            out_row_start: 2,
            out_rows: 2,
            grid: (1, 2),
            tile: 2,
            fidelity: Fidelity::Measured,
            state_vars: 12,
            fro_error: 0.0625,
            cache_hit: false,
        },
        JobResult::Rejected { reason: "nope".into() },
        JobResult::Submitted { ticket: 17 },
        JobResult::Pending { ticket: 17 },
    ];
    for result in results {
        assert_eq!(JobResult::decode(&result.encode()).expect("round trip"), result);
    }
}

/// The pinned v2 → v3 upgrade rules (see `service::compat`).
#[test]
fn v2_documents_decode_through_the_compat_shim() {
    // Rule 1: the four legacy job kinds decode identically under v2 — a
    // v3 encoding with the version tag rewritten to 2 yields the same job.
    let legacy_jobs = vec![
        Job::Infer { processor: "m".into(), image: vec![0.5, 0.25] },
        Job::Classify { processor: "c".into(), classifier: 2, point: [1.0, -2.0] },
        Job::RawApply { processor: "p".into(), x: CMat::eye(2) },
        Job::Reprogram { processor: "p".into(), code: vec![1, 4] },
    ];
    for job in legacy_jobs {
        let mut doc = parse(&job.encode()).unwrap();
        if let Json::Obj(map) = &mut doc {
            map.insert("v".into(), Json::Num(compat::WIRE_VERSION_V2 as f64));
        }
        let as_v2 = doc.to_string_compact();
        assert_eq!(Job::decode(&as_v2).expect("v2 decodes via the shim"), job, "{as_v2}");
        // The shim entry point agrees with the dispatching decoder.
        assert_eq!(compat::job_from_v2(&doc).unwrap(), job);
    }
    // Same for the five legacy result kinds.
    let legacy_results = vec![
        JobResult::Infer { probs: vec![0.2; 10], queued_us: 3, service_us: 4 },
        JobResult::Classify { yhat: 0.5, reconfigured: false },
        JobResult::RawApply { y: CMat::eye(3) },
        JobResult::Reprogrammed { version: 9 },
        JobResult::Rejected { reason: "why".into() },
    ];
    for result in legacy_results {
        let mut doc = parse(&result.encode()).unwrap();
        if let Json::Obj(map) = &mut doc {
            map.insert("v".into(), Json::Num(compat::WIRE_VERSION_V2 as f64));
        }
        assert_eq!(JobResult::decode(&doc.to_string_compact()).unwrap(), result);
    }
    // Rule 2: v3-only kinds are refused inside a v2 document.
    let compile = Job::Compile {
        name: "virt".into(),
        target: CMat::eye(2),
        tile: 2,
        fidelity: Fidelity::Digital,
    };
    let mut doc = parse(&compile.encode()).unwrap();
    if let Json::Obj(map) = &mut doc {
        map.insert("v".into(), Json::Num(compat::WIRE_VERSION_V2 as f64));
    }
    let err = Job::decode(&doc.to_string_compact()).expect_err("compile needs v3");
    assert!(err.to_string().contains("version 3"), "{err}");
    let shard = Job::ShardCompile { name: "net.s1".into(), spec: fixed_shard_spec() };
    let mut doc = parse(&shard.encode()).unwrap();
    if let Json::Obj(map) = &mut doc {
        map.insert("v".into(), Json::Num(compat::WIRE_VERSION_V2 as f64));
    }
    let err = Job::decode(&doc.to_string_compact()).expect_err("shard_compile needs v3");
    assert!(err.to_string().contains("version 3"), "{err}");
    assert!(compat::result_from_v2(
        &parse(r#"{"v":2,"kind":"compiled","name":"x","version":1}"#).unwrap()
    )
    .is_err());
    let err = compat::result_from_v2(
        &parse(r#"{"v":2,"kind":"shard_compiled","name":"x","version":1}"#).unwrap(),
    )
    .expect_err("shard_compiled needs v3");
    assert!(err.to_string().contains("version 3"), "{err}");
    // Rule 3: encoders never emit v2.
    let job = Job::Reprogram { processor: "p".into(), code: vec![0] };
    let v = parse(&job.encode()).unwrap();
    assert_eq!(v.get("v").and_then(Json::as_f64), Some(WIRE_VERSION as f64));
    // Rule 4: versions other than 2, 3, and 4 are refused outright.
    for bad in [0u64, 1, 5, 99] {
        let text = format!(r#"{{"v":{bad},"kind":"infer","processor":"m","image":[]}}"#);
        assert!(Job::decode(&text).is_err(), "v{bad} must be refused");
    }
}

/// The pinned v3 → v4 upgrade rules: every v3 kind decodes identically
/// through the shim, and the v4 poll-mode kinds (`poll` jobs;
/// `submitted` / `pending` results) are refused in v2 AND v3 documents.
#[test]
fn v3_documents_decode_through_the_compat_shim() {
    // Rule 1: the whole v3 schema (all six job kinds, all seven result
    // kinds) decodes identically with the version tag rewritten to 3.
    let v3_jobs = vec![
        Job::Infer { processor: "m".into(), image: vec![0.5, 0.25] },
        Job::Classify { processor: "c".into(), classifier: 2, point: [1.0, -2.0] },
        Job::RawApply { processor: "p".into(), x: CMat::eye(2) },
        Job::Reprogram { processor: "p".into(), code: vec![1, 4] },
        Job::Compile {
            name: "virt".into(),
            target: CMat::eye(2),
            tile: 2,
            fidelity: Fidelity::Digital,
        },
        Job::ShardCompile { name: "net.s1".into(), spec: fixed_shard_spec() },
    ];
    for job in v3_jobs {
        let mut doc = parse(&job.encode()).unwrap();
        if let Json::Obj(map) = &mut doc {
            map.insert("v".into(), Json::Num(compat::WIRE_VERSION_V3 as f64));
        }
        let as_v3 = doc.to_string_compact();
        assert_eq!(Job::decode(&as_v3).expect("v3 decodes via the shim"), job, "{as_v3}");
        assert_eq!(compat::job_from_v3(&doc).unwrap(), job);
    }
    let v3_results = vec![
        JobResult::Infer { probs: vec![0.2; 10], queued_us: 3, service_us: 4 },
        JobResult::Classify { yhat: 0.5, reconfigured: false },
        JobResult::RawApply { y: CMat::eye(3) },
        JobResult::Reprogrammed { version: 9 },
        JobResult::Compiled {
            name: "virt".into(),
            version: 1,
            grid: (2, 1),
            tile: 2,
            fidelity: Fidelity::Quantized,
            state_vars: 16,
            fro_error: 0.125,
            cache_hit: true,
        },
        JobResult::ShardCompiled {
            name: "net.s1".into(),
            version: 1,
            out_row_start: 2,
            out_rows: 2,
            grid: (1, 2),
            tile: 2,
            fidelity: Fidelity::Measured,
            state_vars: 12,
            fro_error: 0.0625,
            cache_hit: false,
        },
        JobResult::Rejected { reason: "why".into() },
    ];
    for result in v3_results {
        let mut doc = parse(&result.encode()).unwrap();
        if let Json::Obj(map) = &mut doc {
            map.insert("v".into(), Json::Num(compat::WIRE_VERSION_V3 as f64));
        }
        assert_eq!(JobResult::decode(&doc.to_string_compact()).unwrap(), result);
        assert_eq!(compat::result_from_v3(&doc).unwrap(), result);
    }
    // Rule 2: the poll-mode kinds are v4-only — refused in v3 AND v2.
    for old in [compat::WIRE_VERSION_V2, compat::WIRE_VERSION_V3] {
        let err = Job::decode(&format!(r#"{{"v":{old},"kind":"poll","ticket":7}}"#))
            .expect_err("poll is v4-only");
        assert!(err.to_string().contains("version 4"), "{err}");
        for kind in ["submitted", "pending"] {
            let err = JobResult::decode(&format!(r#"{{"v":{old},"kind":"{kind}","ticket":7}}"#))
                .expect_err("poll-mode results are v4-only");
            assert!(err.to_string().contains("version 4"), "{err}");
        }
    }
    // Rule 3: encoders never emit v3.
    let v = parse(&Job::Poll { ticket: 1 }.encode()).unwrap();
    assert_eq!(v.get("v").and_then(Json::as_f64), Some(WIRE_VERSION as f64));
    assert_eq!(v.get("kind").and_then(Json::as_str), Some("poll"));
}

/// Malformed poll-mode documents are refused, never panicking and never
/// truncating a ticket id.
#[test]
fn poll_decode_rejects_malformed_tickets() {
    assert!(Job::decode(&format!(r#"{{"v":{WIRE_VERSION},"kind":"poll"}}"#)).is_err());
    assert!(Job::decode(&format!(r#"{{"v":{WIRE_VERSION},"kind":"poll","ticket":-1}}"#)).is_err());
    assert!(
        Job::decode(&format!(r#"{{"v":{WIRE_VERSION},"kind":"poll","ticket":1.5}}"#)).is_err()
    );
    assert!(
        Job::decode(&format!(r#"{{"v":{WIRE_VERSION},"kind":"poll","ticket":"7"}}"#)).is_err()
    );
    assert!(JobResult::decode(&format!(r#"{{"v":{WIRE_VERSION},"kind":"submitted"}}"#)).is_err());
    assert!(
        JobResult::decode(&format!(r#"{{"v":{WIRE_VERSION},"kind":"pending","ticket":null}}"#))
            .is_err()
    );
    // Fuzz: random junk tickets must refuse or round-trip, never panic.
    forall("poll ticket fuzz", 150, |g| {
        let n = g.usize_in(0, 24);
        let junk: String =
            (0..n).map(|_| char::from(g.usize_in(32, 126) as u8)).collect();
        let text = format!(r#"{{"v":{WIRE_VERSION},"kind":"poll","ticket":{junk}}}"#);
        if let Ok(job) = Job::decode(&text) {
            assert_eq!(Job::decode(&job.encode()).unwrap(), job);
        }
    });
}

#[test]
fn decode_rejects_unknown_wire_version() {
    let job = Job::Infer { processor: "m".into(), image: vec![0.5] };
    // Stamp a future version onto an otherwise-valid document.
    let mut v = parse(&job.encode()).unwrap();
    if let Json::Obj(map) = &mut v {
        map.insert("v".into(), Json::Num((WIRE_VERSION + 1) as f64));
    } else {
        panic!("wire form must be an object");
    }
    let err = Job::decode(&v.to_string_compact()).expect_err("future version must be refused");
    assert!(err.to_string().contains("unsupported version"), "{err}");
    // Same gate on results.
    let err = JobResult::decode(&format!(
        r#"{{"v":{},"kind":"rejected","reason":"x"}}"#,
        WIRE_VERSION + 7
    ))
    .expect_err("future version must be refused");
    assert!(err.to_string().contains("unsupported version"), "{err}");
    // And a missing version tag is not treated as current.
    assert!(Job::decode(r#"{"kind":"infer","processor":"m","image":[]}"#).is_err());
}

#[test]
fn decode_rejects_non_integer_index_fields() {
    // A truncating cast would accept all of these: 2.5 → version 2
    // (defeating the gate), -1 → classifier 0 (a real classifier).
    assert!(Job::decode(r#"{"v":2.5,"kind":"infer","processor":"m","image":[]}"#).is_err());
    assert!(Job::decode(&format!(
        r#"{{"v":{WIRE_VERSION},"kind":"classify","processor":"c","classifier":-1,"point":[1,2]}}"#
    ))
    .is_err());
    assert!(Job::decode(&format!(
        r#"{{"v":{WIRE_VERSION},"kind":"classify","processor":"c","classifier":1.5,"point":[1,2]}}"#
    ))
    .is_err());
    assert!(Job::decode(&format!(
        r#"{{"v":{WIRE_VERSION},"kind":"reprogram","processor":"p","code":[1,-3]}}"#
    ))
    .is_err());
}

#[test]
fn non_finite_values_survive_the_wire_as_nan() {
    // JSON has no NaN/Inf literal: the encoder writes null, the decoder
    // maps null back to NaN, so encoding a degenerate result (exactly the
    // case nan_safe_argmax exists for) stays decodable by its peer.
    let r = JobResult::Infer { probs: vec![f32::NAN, 0.5], queued_us: 1, service_us: 2 };
    match JobResult::decode(&r.encode()).expect("null entries decode as NaN") {
        JobResult::Infer { probs, .. } => {
            assert!(probs[0].is_nan());
            assert_eq!(probs[1], 0.5);
        }
        other => panic!("unexpected {other:?}"),
    }
    let j = Job::Classify {
        processor: "c".into(),
        classifier: 0,
        point: [f64::INFINITY, 1.0],
    };
    match Job::decode(&j.encode()).expect("non-finite point decodes") {
        Job::Classify { point, .. } => {
            assert!(point[0].is_nan(), "Inf has no JSON form; null → NaN");
            assert_eq!(point[1], 1.0);
        }
        other => panic!("unexpected {other:?}"),
    }
}

/// The optional distributed-tracing envelope: `trace` on a v3 job request
/// round-trips exactly, and an untraced request puts no `trace` key on
/// the wire at all (v2 peers and old servers see the same bytes as
/// before tracing existed).
#[test]
fn trace_annotated_requests_round_trip() {
    use crate::obs::trace::WireTrace;
    let req = Request::Job {
        id: 9,
        job: Job::Reprogram { processor: "p".into(), code: vec![1, 2] },
        trace: Some(WireTrace { trace: 123_456_789, parent: 42 }),
    };
    assert_eq!(Request::decode(&req.encode()).expect("traced request decodes"), req);
    let bare = Request::Job {
        id: 1,
        job: Job::Reprogram { processor: "p".into(), code: vec![] },
        trace: None,
    };
    assert!(!bare.encode().contains("\"trace\""), "untraced must stay silent");
    assert_eq!(Request::decode(&bare.encode()).unwrap(), bare);
    // Random contexts round-trip across the whole 2^53 JSON-safe range.
    forall("wire trace round-trip", 100, |g| {
        let wt = WireTrace {
            trace: g.usize_in(0, (1 << 53) - 1) as u64,
            parent: g.usize_in(0, (1 << 53) - 1) as u64,
        };
        assert_eq!(WireTrace::from_json(&wt.to_json()), Some(wt));
    });
}

/// The pinned forward-compat rule: a malformed or unknown `trace` field
/// on a v3 request is IGNORED — the job decodes with `trace: None` —
/// never rejected; and a response envelope's `trace` payload rides
/// outside the typed [`Response`], so it never disturbs that decode.
#[test]
fn malformed_trace_degrades_to_untraced_never_rejects() {
    let req = Request::Job {
        id: 4,
        job: Job::Classify { processor: "c".into(), classifier: 1, point: [0.5, -1.0] },
        trace: None,
    };
    let base = parse(&req.encode()).unwrap();
    let hostile = [
        Json::Str("not an object".into()),
        Json::Num(7.0),
        Json::Bool(true),
        Json::Arr(vec![Json::Num(1.0)]),
        Json::obj(vec![]), // both ids missing
        Json::obj(vec![("trace", Json::Num(1.5)), ("parent", Json::Num(2.0))]),
        Json::obj(vec![("trace", Json::Num(-3.0)), ("parent", Json::Num(2.0))]),
        Json::obj(vec![("trace", Json::Num(9.1e15)), ("parent", Json::Num(2.0))]),
        Json::obj(vec![("trace", Json::Str("x".into())), ("parent", Json::Num(2.0))]),
    ];
    for bad in hostile {
        let mut doc = base.clone();
        if let Json::Obj(map) = &mut doc {
            map.insert("trace".into(), bad.clone());
        }
        match Request::decode(&doc.to_string_compact()) {
            Ok(Request::Job { id, trace, .. }) => {
                assert_eq!(id, 4);
                assert_eq!(trace, None, "hostile trace {bad:?} must be ignored");
            }
            other => panic!("hostile trace {bad:?} must not reject: {other:?}"),
        }
    }
    // Fuzz: splice arbitrary JSON fragments in as `trace` — the request
    // must still decode (traced only when the fragment happens valid).
    forall("hostile trace shapes", 150, |g| {
        let n = g.usize_in(0, 40);
        let blob: Vec<u8> = (0..n).map(|_| g.usize_in(0, 255) as u8).collect();
        let frag = parse(&String::from_utf8_lossy(&blob)).unwrap_or(Json::Null);
        let mut doc = base.clone();
        if let Json::Obj(map) = &mut doc {
            map.insert("trace".into(), frag);
        }
        assert!(Request::decode(&doc.to_string_compact()).is_ok());
    });
    // Response side: attach a span payload where the server would.
    let resp = Response::Result {
        id: 4,
        result: JobResult::Classify { yhat: 0.25, reconfigured: false },
    };
    let mut doc = parse(&resp.encode()).unwrap();
    if let Json::Obj(map) = &mut doc {
        let span = Json::obj(vec![("name", Json::Str("exec".into()))]);
        map.insert("trace".into(), Json::obj(vec![("spans", Json::Arr(vec![span]))]));
    }
    assert_eq!(Response::decode(&doc.to_string_compact()).unwrap(), resp);
}

/// Hostile-input sweep: random byte blobs and mutated documents through
/// every decoder (jobs, results, admin, transport envelopes, framing)
/// must refuse, never panic — the server runs these paths on whatever a
/// socket delivers.
#[test]
fn decoders_never_panic_on_garbage() {
    forall("decoders on garbage", 300, |g| {
        let n = g.usize_in(0, 80);
        let blob: Vec<u8> = (0..n).map(|_| g.usize_in(0, 255) as u8).collect();
        let text = String::from_utf8_lossy(&blob).to_string();
        let _ = Job::decode(&text);
        let _ = JobResult::decode(&text);
        let _ = Admin::decode(&text);
        let _ = AdminReply::decode(&text);
        let _ = Request::decode(&text);
        let _ = Response::decode(&text);
        let _ = read_frame(&mut std::io::Cursor::new(blob), 1 << 16);
        // Mutate one byte of a valid document: still must not panic.
        let valid = Job::Classify { processor: "c".into(), classifier: 1, point: [1.0, 2.0] }
            .encode()
            .into_bytes();
        let mut mutated = valid.clone();
        let at = g.usize_in(0, mutated.len() - 1);
        mutated[at] = g.usize_in(0, 255) as u8;
        let _ = Job::decode(&String::from_utf8_lossy(&mutated));
    });
}

#[test]
fn decode_rejects_malformed_documents() {
    assert!(Job::decode("not json at all").is_err());
    assert!(Job::decode(&format!(r#"{{"v":{WIRE_VERSION}}}"#)).is_err()); // no kind
    assert!(Job::decode(&format!(r#"{{"v":{WIRE_VERSION},"kind":"warp","processor":"m"}}"#))
        .is_err()); // unknown kind
    // classify needs exactly two coordinates
    assert!(Job::decode(&format!(
        r#"{{"v":{WIRE_VERSION},"kind":"classify","processor":"c","classifier":0,"point":[1,2,3]}}"#
    ))
    .is_err());
    // matrix with inconsistent shape/data
    assert!(Job::decode(&format!(
        r#"{{"v":{WIRE_VERSION},"kind":"raw_apply","processor":"p","x":{{"rows":2,"cols":2,"re":[1,2,3],"im":[0,0,0,0]}}}}"#
    ))
    .is_err());
    // absurd matrix dims must be refused before allocating
    assert!(Job::decode(&format!(
        r#"{{"v":{WIRE_VERSION},"kind":"raw_apply","processor":"p","x":{{"rows":1000000,"cols":1000000,"re":[],"im":[]}}}}"#
    ))
    .is_err());
    // compile: weight arrays must match rows×cols exactly
    assert!(Job::decode(&format!(
        r#"{{"v":{WIRE_VERSION},"kind":"compile","name":"v","rows":2,"cols":2,"re":[1,2,3],"im":[0,0,0,0],"tile":2,"fidelity":"quantized"}}"#
    ))
    .is_err());
    // compile: unknown fidelity names are refused at decode
    assert!(Job::decode(&format!(
        r#"{{"v":{WIRE_VERSION},"kind":"compile","name":"v","rows":1,"cols":1,"re":[1],"im":[0],"tile":2,"fidelity":"analog"}}"#
    ))
    .is_err());
    // compile: oversized weight matrices are refused before allocating
    assert!(Job::decode(&format!(
        r#"{{"v":{WIRE_VERSION},"kind":"compile","name":"v","rows":100000,"cols":100000,"re":[],"im":[],"tile":8,"fidelity":"digital"}}"#
    ))
    .is_err());
    // shard_compile: the slice height is DERIVED from the geometry — a
    // payload sized for the wrong slice is refused at decode.
    let mut good = parse(&Job::ShardCompile { name: "s".into(), spec: fixed_shard_spec() }.encode())
        .unwrap();
    assert!(Job::decode(&good.to_string_compact()).is_ok());
    if let Json::Obj(map) = &mut good {
        // Widen the claimed window: the derived slice height no longer
        // matches the 2×4 payload that rode along.
        map.insert("row_start".into(), Json::Num(0.0));
        map.insert("grid_rows".into(), Json::Num(9.0));
    }
    assert!(Job::decode(&good.to_string_compact()).is_err(), "mis-sized shard slice");
    // shard_compile: a window past the end of the matrix owns no rows
    assert!(Job::decode(&format!(
        r#"{{"v":{WIRE_VERSION},"kind":"shard_compile","name":"s","rows":4,"cols":2,"tile":2,"fidelity":"digital","seed":0,"calibration":"ideal","row_start":7,"grid_rows":1,"re":[],"im":[]}}"#
    ))
    .is_err());
    // shard_compile: unknown calibration rules are refused at decode
    assert!(Job::decode(&format!(
        r#"{{"v":{WIRE_VERSION},"kind":"shard_compile","name":"s","rows":2,"cols":2,"tile":2,"fidelity":"digital","seed":0,"calibration":"warp","row_start":0,"grid_rows":1,"re":[1,2,3,4],"im":[0,0,0,0]}}"#
    ))
    .is_err());
}
