//! Property tests for the [`LinearProcessor`] execution contract:
//! `apply_batch` over any backend must equal the column-by-column `matvec`
//! of its composed matrix, which in turn must equal a naive triple-loop
//! reference (so the blocked GEMM cannot be "self-consistently wrong").
//!
//! Backends covered: the digital `CMat` reference, the ideal analytic
//! mesh, the measured (virtual-VNA) mesh, and the Table-I-quantized mesh.
//! Dims 2–16, batch 1–64, per the PR-1 contract.

use super::prop::{forall_seeded, Gen};
use crate::math::c64::C64;
use crate::math::cmat::CMat;
use crate::math::gemm::{self, Micro};
use crate::math::svd::svd;
use crate::mesh::propagate::{DiscreteMesh, MeshBackend};
use crate::mesh::quantize::QuantizedMesh;
use crate::processor::LinearProcessor;

/// Naive `M·X` reference: the O(m·k·n) triple loop, no blocking.
fn naive_gemm(m: &CMat, x: &CMat) -> CMat {
    assert_eq!(m.cols(), x.rows());
    CMat::from_fn(m.rows(), x.cols(), |i, j| {
        let mut acc = C64::ZERO;
        for k in 0..m.cols() {
            acc += m[(i, k)] * x[(k, j)];
        }
        acc
    })
}

/// A random complex batch matrix.
fn gen_batch(g: &mut Gen, rows: usize, batch: usize) -> CMat {
    let data: Vec<C64> =
        (0..rows * batch).map(|_| C64::new(g.f64_in(-2.0, 2.0), g.f64_in(-2.0, 2.0))).collect();
    CMat::from_rows(rows, batch, &data)
}

/// The contract under test, for one backend instance.
fn check_processor(p: &dyn LinearProcessor, g: &mut Gen, tol: f64) {
    let (out, inp) = p.dims();
    let batch = g.usize_in(1, 64);
    let x = gen_batch(g, inp, batch);
    let y = p.apply_batch(&x);
    assert_eq!((y.rows(), y.cols()), (out, batch));
    let reference = naive_gemm(p.matrix(), &x);
    for j in 0..batch {
        // Column-by-column matvec (the replaced per-vector hot path)…
        let col = p.apply(&x.col(j));
        for i in 0..out {
            assert!(
                (y[(i, j)] - col[i]).abs() < tol,
                "batch≠matvec at ({i},{j}): {:?} vs {:?}",
                y[(i, j)],
                col[i]
            );
            // …and the naive reference.
            assert!(
                (y[(i, j)] - reference[(i, j)]).abs() < tol,
                "batch≠naive at ({i},{j})"
            );
        }
    }
}

/// A random unitary (SVD of a random complex matrix, singular values
/// snapped to 1).
fn gen_unitary(g: &mut Gen, n: usize) -> CMat {
    let a = CMat::from_fn(n, n, |_, _| C64::new(g.normal(), g.normal()));
    let f = svd(&a);
    f.u.matmul(&f.vh)
}

#[test]
fn digital_cmat_apply_batch_matches_matvec() {
    forall_seeded("digital CMat batch ≡ matvec", 0xD161, 30, |g| {
        let out = g.usize_in(2, 16);
        let inp = g.usize_in(2, 16);
        let m = CMat::from_fn(out, inp, |_, _| C64::new(g.normal(), g.normal()));
        check_processor(&m, g, 1e-11);
    });
}

#[test]
fn ideal_mesh_apply_batch_matches_matvec() {
    forall_seeded("ideal mesh batch ≡ matvec", 0x1DEA, 12, |g| {
        let n = g.usize_in(2, 16);
        let mut mesh = DiscreteMesh::new(n, MeshBackend::Ideal);
        let states: Vec<usize> = (0..2 * mesh.cells()).map(|_| g.usize_in(0, 5)).collect();
        mesh.set_encoded(&states);
        check_processor(&mesh, g, 1e-11);
    });
}

#[test]
fn measured_mesh_apply_batch_matches_matvec() {
    // Fewer cases: each measured mesh fabricates N(N−1)/2 virtual-VNA
    // devices (36 circuit evaluations apiece).
    forall_seeded("measured mesh batch ≡ matvec", 0x3EA5, 5, |g| {
        let n = g.usize_in(2, 16);
        let seed = g.usize_in(0, 1 << 20) as u64;
        let mut mesh = DiscreteMesh::new(n, MeshBackend::Measured { base_seed: seed });
        let states: Vec<usize> = (0..2 * mesh.cells()).map(|_| g.usize_in(0, 5)).collect();
        mesh.set_encoded(&states);
        check_processor(&mesh, g, 1e-11);
    });
}

/// ulp distance between two finite f64s: 0 for bit-identical values
/// (including `0.0 == -0.0`), the bit-pattern distance within a sign, and
/// "far" for sign-crossing pairs.
fn ulp_diff(a: f64, b: f64) -> u64 {
    assert!(a.is_finite() && b.is_finite(), "non-finite kernel output: {a} vs {b}");
    if a == b {
        return 0;
    }
    if a.is_sign_negative() != b.is_sign_negative() {
        return u64::MAX;
    }
    a.abs().to_bits().abs_diff(b.abs().to_bits())
}

/// Run one `(m, k, n)` shape through every microkernel the dispatcher can
/// select (all scalar MR/NR blockings, plus AVX2 when this machine has
/// it) and assert agreement with the scalar 4×4 reference within 4 ulp —
/// the kernel-equivalence contract of `crate::math::gemm`. (The current
/// kernels are in fact bit-identical; 4 ulp is the documented headroom
/// for a future fused kernel.)
fn check_kernels_agree(g: &mut Gen, m: usize, k: usize, n: usize) {
    let a: Vec<C64> =
        (0..m * k).map(|_| C64::new(g.f64_in(-2.0, 2.0), g.f64_in(-2.0, 2.0))).collect();
    let b: Vec<C64> =
        (0..k * n).map(|_| C64::new(g.f64_in(-2.0, 2.0), g.f64_in(-2.0, 2.0))).collect();
    let mut reference = vec![C64::ZERO; m * n];
    gemm::gemm_into_micro(Micro::Scalar { mr: 4, nr: 4 }, &a, &b, &mut reference, m, k, n);
    let mut micros: Vec<Micro> = gemm::scalar_candidates().to_vec();
    if gemm::avx2_available() {
        micros.push(Micro::Avx2);
    }
    for micro in micros {
        // Start from poisoned memory so "kernel skipped an entry" fails.
        let mut got = vec![C64::new(f64::NAN, f64::NAN); m * n];
        gemm::gemm_into_micro(micro, &a, &b, &mut got, m, k, n);
        for (i, (y, want)) in got.iter().zip(&reference).enumerate() {
            let (dr, di) = (ulp_diff(y.re, want.re), ulp_diff(y.im, want.im));
            assert!(
                dr <= 4 && di <= 4,
                "{} vs scalar4x4 at {m}x{k}x{n} entry {i}: {y:?} vs {want:?} ({dr}/{di} ulp)",
                micro.label()
            );
        }
    }
}

/// PR-6 satellite: SIMD-vs-scalar kernel equivalence across shapes that
/// straddle every MR/NR block edge — m=1 row sweeps, n=1 matvecs, ragged
/// tiles around 4 and 8, and the serving batch sizes 1/8/64.
#[test]
fn simd_and_scalar_kernels_agree_within_4_ulp() {
    forall_seeded("kernel equivalence (pinned shapes)", 0x51AD, 1, |g| {
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (1, 9, 64),
            (2, 2, 1),
            (3, 5, 7),
            (4, 4, 8),
            (5, 4, 3),
            (7, 3, 5),
            (8, 8, 64),
            (9, 7, 65),
            (16, 16, 1),
        ] {
            check_kernels_agree(g, m, k, n);
        }
    });
    forall_seeded("kernel equivalence (random shapes)", 0x51AE, 25, |g| {
        let m = g.usize_in(1, 18);
        let k = g.usize_in(1, 18);
        let n = *g.choose(&[1usize, 2, 3, 4, 5, 8, 9, 64]);
        check_kernels_agree(g, m, k, n);
    });
}

#[test]
fn quantized_mesh_apply_batch_matches_matvec() {
    forall_seeded("quantized mesh batch ≡ matvec", 0x9A47, 8, |g| {
        let n = g.usize_in(2, 16);
        let u = gen_unitary(g, n);
        let q = QuantizedMesh::program_unitary(&u, MeshBackend::Ideal);
        check_processor(&q, g, 1e-11);
    });
}
