//! Test-support toolkit.
//!
//! The offline vendor set has no `proptest`, so [`prop`] provides a small
//! in-repo property-testing harness: seeded generators, a `forall` runner
//! with failure reproduction info, and shrinking for the common scalar/vec
//! shapes used by the library's invariant tests. `processor_props` holds
//! the cross-backend [`crate::processor::LinearProcessor`] execution
//! contract (`apply_batch` ≡ column-by-column `matvec` ≡ naive reference);
//! `wire_props` holds the serving wire-protocol contract (every
//! `Job`/`JobResult` variant round-trips under `WIRE_VERSION`, unknown
//! versions are refused); `tiling_props` holds the tiling compiler's
//! execution contract (digital virtualization ≡ dense GEMM; quantized
//! virtualization inside the compile-reported error band; ragged shapes
//! and every physical tile size).

pub mod prop;

#[cfg(test)]
mod processor_props;

#[cfg(test)]
mod tiling_props;

#[cfg(test)]
mod wire_props;
