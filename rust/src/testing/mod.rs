//! Test-support toolkit.
//!
//! The offline vendor set has no `proptest`, so [`prop`] provides a small
//! in-repo property-testing harness: seeded generators, a `forall` runner
//! with failure reproduction info, and shrinking for the common scalar/vec
//! shapes used by the library's invariant tests.

pub mod prop;
