//! `cargo bench` entrypoint (harness = false): regenerate every paper
//! table/figure through the in-repo harness, then run the §Perf
//! micro-benchmarks. criterion is unavailable offline — see
//! rfnn::bench::harness for the timing methodology.

fn main() {
    let quick = std::env::var("RFNN_BENCH_FULL").is_err();
    if quick {
        eprintln!("(quick mode; set RFNN_BENCH_FULL=1 for full workloads)");
    }
    for name in rfnn::bench::EXPERIMENTS {
        println!("=== {name} ===");
        match rfnn::bench::run(name, quick) {
            Ok(report) => println!("{report}"),
            Err(e) => {
                eprintln!("FAILED {name}: {e}");
                std::process::exit(1);
            }
        }
    }
}
