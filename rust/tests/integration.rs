//! Cross-module integration tests: physics → mesh → network → serving.

use rfnn::dataset::mnist::synthetic;
use rfnn::dataset::synth2d::{generate, Scenario};
use rfnn::device::circuit::UnitCellCircuit;
use rfnn::device::ideal;
use rfnn::device::testbench::TestBench;
use rfnn::device::vna::MeasuredUnitCell;
use rfnn::device::State;
use rfnn::math::c64::C64;
use rfnn::math::cmat::CMat;
use rfnn::math::deg;
use rfnn::math::rng::Rng;
use rfnn::mesh::decompose::{decompose_unitary, synthesize_real};
use rfnn::mesh::propagate::{DiscreteMesh, MeshBackend};
use rfnn::mesh::quantize::quantize_program;
use rfnn::microwave::phase_shifter::TABLE_I_DEG;
use rfnn::microwave::touchstone::Touchstone;
use rfnn::microwave::F0;
use rfnn::nn::rfnn2x2;
use rfnn::nn::rfnn_mnist::{MnistRfnn, MnistTrainConfig};
use rfnn::nn::sgd::SgdConfig;
use rfnn::testing::prop::forall;

/// Physics → device: the circuit model's forward block approaches eq. (5)
/// up to a common loss factor, for every one of the 36 states.
#[test]
fn circuit_tracks_ideal_across_all_states() {
    let cell = UnitCellCircuit::prototype();
    for st in State::all() {
        let t_circ = cell.t_block(F0, st);
        let t_ideal = ideal::t_matrix(deg(TABLE_I_DEG[st.theta]), deg(TABLE_I_DEG[st.phi]));
        // The circuit block equals D_out · t_ideal up to small error, where
        // D_out = diag(d2, d3) models the two output paths' loss + delay
        // (the φ shifter sits on P2 only). Fit the per-row complex ratio
        // from the dominant entry and check the whole row follows it.
        for row in 0..2 {
            // Dominant entry of this row (avoids dividing by near-nulls).
            let j0 = if t_ideal[(row, 0)].abs() >= t_ideal[(row, 1)].abs() { 0 } else { 1 };
            let d = t_circ[(row, j0)] / t_ideal[(row, j0)];
            assert!(
                (0.3..1.0).contains(&d.abs()),
                "state {} row {row}: output-path gain {} out of physical range",
                st.label(),
                d.abs()
            );
            for j in 0..2 {
                let err = (t_circ[(row, j)] - d * t_ideal[(row, j)]).abs();
                assert!(
                    err < 0.12,
                    "state {} [{row}][{j}]: residual {err} after output-path factor {d:?}",
                    st.label()
                );
            }
        }
    }
}

/// Device → Touchstone → device round trip preserves the transfer block.
#[test]
fn vna_sweep_round_trips_through_touchstone() {
    let dev = MeasuredUnitCell::fabricate(404);
    let st = State { theta: 2, phi: 4 };
    let ts = dev.sweep(st, 1.5e9, 2.5e9, 11);
    let text = ts.to_string_ri();
    let back = Touchstone::parse(&text, 4).unwrap();
    let orig = ts.at(F0).unwrap();
    let loaded = back.at(F0).unwrap();
    assert!(orig.mat().sub(loaded.mat()).max_abs() < 1e-9);
}

/// Mesh → quantize → measured-mesh: a synthesized unitary survives
/// quantization well enough that the measured mesh correlates with it.
#[test]
fn synthesis_quantization_pipeline() {
    let mut rng = Rng::new(77);
    let a = CMat::from_fn(4, 4, |_, _| C64::new(rng.normal(), rng.normal()));
    let f = rfnn::math::svd::svd(&a);
    let u = f.u.matmul(&f.vh);
    let prog = decompose_unitary(&u);
    let q = quantize_program(&prog);
    let mut mesh = DiscreteMesh::new(4, MeshBackend::Ideal);
    mesh.set_states(&q.states);
    // The discrete mesh cannot match exactly (only 36 states/cell), but the
    // magnitudes structure should correlate with the target.
    let got = mesh.matrix();
    let mut corr_num = 0.0;
    let mut n1 = 0.0;
    let mut n2 = 0.0;
    for i in 0..4 {
        for j in 0..4 {
            let x = got[(i, j)].abs();
            let y = u[(i, j)].abs();
            corr_num += x * y;
            n1 += x * x;
            n2 += y * y;
        }
    }
    let cos_sim = corr_num / (n1 * n2).sqrt();
    assert!(cos_sim > 0.55, "cosine similarity {cos_sim}");
    assert!(q.max_error() < 2.9);
}

/// Full analog pipeline: train the 2×2 RFNN on the power test bench of a
/// *circuit-modelled* (not ideal) device and verify generalization.
#[test]
fn rfnn2x2_on_circuit_device_generalizes() {
    let cell = MeasuredUnitCell::fabricate(11);
    let bench = TestBench::new(move |st| cell.t_block(st), 99);
    let dev = |st: State, v1: f64, v4: f64| bench.measure_voltages(st, v1, v4);
    let mut rng = Rng::new(500);
    let all = generate(Scenario::DiagUp, 400, &mut rng);
    let (tr, te) = all.split(0.75, &mut rng);
    let cfg = rfnn2x2::TrainConfig { epochs: 120, ..Default::default() };
    let model = rfnn2x2::train(&dev, &tr, &cfg);
    assert!(model.accuracy(&dev, &te) > 0.85);
}

/// SVD-synthesized mesh executes an arbitrary matrix on *vectors with
/// negative entries* via the complex field (sign lives in phase).
#[test]
fn synthesized_matrix_handles_signed_inputs() {
    let m = CMat::from_real(3, 3, &[0.2, -0.5, 0.1, 0.7, 0.3, -0.2, -0.4, 0.1, 0.6]);
    let syn = synthesize_real(&m);
    forall("signed inputs through mesh", 50, |g| {
        let x: Vec<C64> = (0..3).map(|_| C64::real(g.f64_in(-2.0, 2.0))).collect();
        let via = syn.apply(&x);
        let direct = m.matvec(&x);
        for (a, b) in via.iter().zip(&direct) {
            assert!((*a - *b).abs() < 1e-8, "{a:?} vs {b:?}");
        }
    });
}

/// Training the full MNIST RFNN with every backend completes and the
/// serving bundle reproduces the trained network's predictions.
#[test]
fn trained_network_serving_bundle_consistency() {
    use rfnn::coordinator::server::ModelBundle;
    use rfnn::nn::rfnn_mnist::gather;
    let tr = synthetic(120, 9);
    let mut net = MnistRfnn::analog(8, MeshBackend::Measured { base_seed: 5 }, 5);
    let cfg = MnistTrainConfig {
        epochs: 6,
        sgd: SgdConfig { lr: 0.05, batch_size: 10, momentum: 0.0 },
        ..Default::default()
    };
    net.train(&tr, &cfg);
    let bundle = ModelBundle::from_trained(&net).unwrap();
    // Native bundle forward must agree with the training-time forward.
    let x = gather(&tr, &(0..16).collect::<Vec<_>>());
    let direct = net.infer(&x);
    let xf: Vec<f32> = x.data().iter().map(|&v| v as f32).collect();
    let served = bundle.forward_native(&xf, 16);
    for i in 0..16 {
        // Compare argmax (probabilities go through f32).
        let direct_pred = direct
            .row(i)
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let srow = &served[i * 10..(i + 1) * 10];
        let served_pred =
            srow.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert_eq!(direct_pred, served_pred, "sample {i}");
    }
}

/// PR-2 acceptance: ONE `ProcessorService::submit` front door serves MNIST
/// infer, 2×2 classify, raw-apply and reprogram jobs against multiple
/// pooled processors, concurrently, with reply routing owned by the
/// service and `Reprogram` versioning the processor it rewrites.
#[test]
fn processor_service_front_door_serves_all_job_kinds_concurrently() {
    use rfnn::coordinator::batcher::BatchPolicy;
    use rfnn::coordinator::metrics::JobKind;
    use rfnn::coordinator::server::{Backend, ModelBundle};
    use rfnn::coordinator::service::{
        Job, JobResult, PoolConfig, ProcessorPool, ProcessorService, Workload,
    };
    use rfnn::nn::rfnn2x2::ideal_device;
    use rfnn::processor::LinearProcessor;
    use std::sync::Arc;
    use std::time::Duration;

    let net = MnistRfnn::analog(8, MeshBackend::Ideal, 3);
    let bundle = ModelBundle::from_trained(&net).unwrap();
    // The same bank `rfnn serve` registers — one source of truth.
    let models = rfnn::cli::demo_classifiers();
    let mesh = DiscreteMesh::new(8, MeshBackend::Ideal);
    let n_code = 2 * mesh.cells();
    let baseline = LinearProcessor::matrix(&mesh).clone();

    let cfg = PoolConfig {
        batch: BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(1) },
        ..PoolConfig::default()
    };
    let pool = ProcessorPool::new();
    pool.register("mnist8", Workload::Mnist { bundle, backend: Backend::Native }, cfg).unwrap();
    pool.register("cls2x2", Workload::Classify2x2(models.clone()), cfg).unwrap();
    pool.register("mesh8", Workload::Processor(Box::new(mesh)), cfg).unwrap();
    let svc = Arc::new(ProcessorService::new(pool));

    // Concurrent mixed traffic: every thread exercises every processor.
    let mut threads = Vec::new();
    for t in 0..3usize {
        let svc = svc.clone();
        let models = models.clone();
        let baseline = baseline.clone();
        threads.push(std::thread::spawn(move || {
            let dev = ideal_device();
            for k in 0..10usize {
                let image = vec![((t + k) % 7) as f32 / 7.0; 784];
                match svc
                    .submit(Job::Infer { processor: "mnist8".into(), image })
                    .expect("infer admitted")
                    .wait()
                    .expect("infer answered")
                {
                    JobResult::Infer { probs, .. } => {
                        assert_eq!(probs.len(), 10);
                        let sum: f32 = probs.iter().sum();
                        assert!((sum - 1.0).abs() < 1e-3, "probs sum {sum}");
                    }
                    other => panic!("unexpected infer result {other:?}"),
                }
                let classifier = (t + k) % 6;
                let point = [k as f64, 30.0 - k as f64];
                match svc
                    .submit(Job::Classify { processor: "cls2x2".into(), classifier, point })
                    .expect("classify admitted")
                    .wait()
                    .expect("classify answered")
                {
                    JobResult::Classify { yhat, .. } => {
                        let want = models[classifier].forward(&dev, point);
                        assert!((yhat - want).abs() < 1e-9, "thread {t} job {k}");
                    }
                    other => panic!("unexpected classify result {other:?}"),
                }
                let x = CMat::from_fn(8, 4, |i, j| {
                    C64::new(0.1 * i as f64 - 0.3, 0.05 * j as f64)
                });
                match svc
                    .submit(Job::RawApply { processor: "mesh8".into(), x: x.clone() })
                    .expect("raw admitted")
                    .wait()
                    .expect("raw answered")
                {
                    JobResult::RawApply { y } => {
                        // Workers may be mid-reprogram below only AFTER the
                        // threads join; here the baseline matrix holds.
                        let want = baseline.matmul(&x);
                        assert!(want.sub(&y).max_abs() < 1e-10);
                    }
                    other => panic!("unexpected raw result {other:?}"),
                }
            }
        }));
    }
    for th in threads {
        th.join().unwrap();
    }

    // Reprogram the pooled mesh: version bumps, served matrix changes to
    // exactly what an identically-programmed reference mesh composes.
    assert_eq!(svc.pool().info("mesh8").unwrap().version, 1);
    let code = vec![3usize; n_code];
    match svc
        .submit(Job::Reprogram { processor: "mesh8".into(), code: code.clone() })
        .expect("reprogram admitted")
        .wait()
        .expect("reprogram answered")
    {
        JobResult::Reprogrammed { version } => assert_eq!(version, 2),
        other => panic!("unexpected reprogram result {other:?}"),
    }
    assert_eq!(svc.pool().info("mesh8").unwrap().version, 2);
    let mut reference = DiscreteMesh::new(8, MeshBackend::Ideal);
    reference.set_encoded(&code);
    match svc
        .submit(Job::RawApply { processor: "mesh8".into(), x: CMat::eye(8) })
        .expect("probe admitted")
        .wait()
        .expect("probe answered")
    {
        JobResult::RawApply { y } => {
            assert!(LinearProcessor::matrix(&reference).sub(&y).max_abs() < 1e-12);
            assert!(baseline.sub(&y).max_abs() > 1e-6, "reprogram must change the matrix");
        }
        other => panic!("unexpected probe result {other:?}"),
    }

    // Per-kind accounting: 30 infers, 30 classifies, 31 raw applies,
    // 1 reprogram — all submitted and served, none shed.
    let m = svc.metrics();
    use std::sync::atomic::Ordering;
    assert_eq!(m.job(JobKind::Infer).served.load(Ordering::Relaxed), 30);
    assert_eq!(m.job(JobKind::Classify).served.load(Ordering::Relaxed), 30);
    assert_eq!(m.job(JobKind::RawApply).served.load(Ordering::Relaxed), 31);
    assert_eq!(m.job(JobKind::Reprogram).served.load(Ordering::Relaxed), 1);
    assert_eq!(m.job(JobKind::Reprogram).rejected.load(Ordering::Relaxed), 0);
}

/// Compiler → pool: the full 4-layer MNIST forward served end-to-end
/// through a `Workload::Virtual` processor whose hidden 8×8 stage runs as
/// a fleet of quantized 2×2 tiles — the PR-3 acceptance path (no PJRT).
#[test]
fn mnist_end_to_end_through_quantized_tile_fleet() {
    use rfnn::compiler::{PlanSpec, VirtualProcessor};
    use rfnn::coordinator::batcher::BatchPolicy;
    use rfnn::coordinator::server::ModelBundle;
    use rfnn::coordinator::service::{
        Job, JobResult, PoolConfig, ProcessorPool, ProcessorService, Workload,
    };
    use rfnn::processor::{Fidelity, LinearProcessor};
    use std::time::Duration;

    let net = MnistRfnn::analog(8, MeshBackend::Ideal, 5);
    let bundle = ModelBundle::from_trained(&net).unwrap();
    let target = bundle.mesh.clone();
    let cfg = PoolConfig {
        batch: BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(1) },
        ..PoolConfig::default()
    };
    let pool = ProcessorPool::new();
    pool.register(
        "virt8",
        Workload::Virtual {
            target: target.clone(),
            tile: 2,
            fidelity: Fidelity::Quantized,
            mnist: Some(bundle.clone()),
        },
        cfg,
    )
    .unwrap();
    let svc = ProcessorService::new(pool);

    // The quantized fleet the worker serves, rebuilt locally: what the
    // pooled forward must be running underneath.
    let fleet =
        VirtualProcessor::compile(&target, &PlanSpec::new(2, Fidelity::Quantized)).unwrap();

    // Infer: the digital head/tail around the tiled analog stage produces
    // exactly forward_with(fleet) — checked against a local forward.
    let ds = synthetic(8, 31);
    for k in 0..ds.len() {
        let image: Vec<f32> = ds.images[k].iter().map(|&v| v as f32).collect();
        let probs = match svc
            .submit(Job::Infer { processor: "virt8".into(), image: image.clone() })
            .expect("admitted")
            .wait()
            .expect("answered")
        {
            JobResult::Infer { probs, .. } => probs,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(probs.len(), 10);
        let sum: f32 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "probs must stay a distribution, got Σ={sum}");
        let want = bundle.forward_with(&fleet, &image, 1);
        for (p, w) in probs.iter().zip(&want) {
            assert!((p - w).abs() < 1e-5, "pooled serving must match the local tiled forward");
        }
    }

    // RawApply probes the tiled hidden stage itself.
    match svc
        .submit(Job::RawApply { processor: "virt8".into(), x: CMat::eye(8) })
        .expect("admitted")
        .wait()
        .expect("answered")
    {
        JobResult::RawApply { y } => {
            assert!(LinearProcessor::matrix(&fleet).sub(&y).max_abs() < 1e-12);
        }
        other => panic!("unexpected {other:?}"),
    }

    // Reprogram the whole fleet through one flat state code.
    let code: Vec<usize> =
        fleet.state_code().unwrap().iter().map(|&v| (v + 1) % 6).collect();
    match svc
        .submit(Job::Reprogram { processor: "virt8".into(), code })
        .expect("admitted")
        .wait()
        .expect("answered")
    {
        JobResult::Reprogrammed { version } => assert_eq!(version, 2),
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(svc.pool().info("virt8").unwrap().version, 2);
}

/// PR-4 acceptance: the transport-agnostic serving API end to end over
/// loopback TCP. A `RemoteClient` round-trips every `Job` kind against a
/// `TcpFrontEnd` in the same process — including `Job::Compile`
/// registering a new virtual processor that then serves `RawApply`
/// traffic — with concurrent clients, a v2-compat document, overload
/// shedding observable in the metrics snapshot, and a clean wire-driven
/// shutdown.
#[test]
fn loopback_tcp_serves_every_job_kind_and_admin_plane() {
    use rfnn::compiler::{PlanSpec, VirtualProcessor};
    use rfnn::coordinator::batcher::BatchPolicy;
    use rfnn::coordinator::metrics::JobKind;
    use rfnn::coordinator::router::{Admin, AdminReply, Router};
    use rfnn::coordinator::server::{Backend, ModelBundle};
    use rfnn::coordinator::service::{
        Job, JobResult, PoolConfig, ProcessorPool, ProcessorService, Workload,
    };
    use rfnn::coordinator::transport::{
        read_frame, write_frame, RemoteClient, Response, TcpConfig, TcpFrontEnd, MAX_FRAME,
    };
    use rfnn::processor::{Fidelity, LinearProcessor};
    use std::sync::atomic::Ordering;
    use std::sync::Arc;
    use std::time::Duration;

    // The server: the usual three-workload pool plus a deliberately
    // stalled external queue (depth 1, never drained until we say so).
    let net = MnistRfnn::analog(8, MeshBackend::Ideal, 3);
    let bundle = ModelBundle::from_trained(&net).unwrap();
    let models = rfnn::cli::demo_classifiers();
    let mesh = DiscreteMesh::new(8, MeshBackend::Ideal);
    let n_code = 2 * mesh.cells();
    let cfg = PoolConfig {
        batch: BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(1) },
        ..PoolConfig::default()
    };
    let pool = ProcessorPool::new();
    pool.register(
        "mnist8",
        Workload::Mnist { bundle: bundle.clone(), backend: Backend::Native },
        cfg,
    )
    .unwrap();
    pool.register("cls2x2", Workload::Classify2x2(models.clone()), cfg).unwrap();
    pool.register("mesh8", Workload::Processor(Box::new(mesh)), cfg).unwrap();
    let stall_rx = pool
        .register_external(
            "stall",
            (2, 2),
            Fidelity::Digital,
            &[JobKind::RawApply],
            PoolConfig { queue_depth: 1, ..PoolConfig::default() },
        )
        .unwrap();
    let svc = Arc::new(ProcessorService::new(pool));
    let router = Arc::new(Router::new(svc.clone()));
    let fe = TcpFrontEnd::bind("127.0.0.1:0", router.clone(), TcpConfig::default())
        .expect("bind ephemeral loopback port");
    let addr = fe.local_addr().to_string();

    // Concurrent clients: every thread opens its own connection and
    // exercises infer + classify + raw-apply.
    let baseline = {
        let m = DiscreteMesh::new(8, MeshBackend::Ideal);
        LinearProcessor::matrix(&m).clone()
    };
    let mut threads = Vec::new();
    for t in 0..3usize {
        let addr = addr.clone();
        let models = models.clone();
        let bundle = bundle.clone();
        let baseline = baseline.clone();
        threads.push(std::thread::spawn(move || {
            let client = RemoteClient::connect(&addr).expect("connect");
            let dev = rfnn::nn::rfnn2x2::ideal_device();
            for k in 0..4usize {
                let image: Vec<f32> =
                    (0..784).map(|i| ((i + 7 * t + k) % 13) as f32 / 13.0).collect();
                match client
                    .submit_wait(Job::Infer { processor: "mnist8".into(), image: image.clone() })
                    .expect("infer served")
                {
                    JobResult::Infer { probs, .. } => {
                        let want = bundle.forward_native(&image, 1);
                        for (p, w) in probs.iter().zip(&want) {
                            assert!((p - w).abs() < 1e-4, "remote infer must match local forward");
                        }
                    }
                    other => panic!("unexpected infer result {other:?}"),
                }
                let classifier = (t + k) % 6;
                let point = [k as f64 + 1.0, 20.0 - k as f64];
                match client
                    .submit_wait(Job::Classify { processor: "cls2x2".into(), classifier, point })
                    .expect("classify served")
                {
                    JobResult::Classify { yhat, .. } => {
                        let want = models[classifier].forward(&dev, point);
                        assert!((yhat - want).abs() < 1e-9);
                    }
                    other => panic!("unexpected classify result {other:?}"),
                }
                // Pipelined submits on one connection resolve out of order
                // safely (demuxed by id).
                let x = CMat::from_fn(8, 3, |i, j| C64::new(0.1 * i as f64, 0.02 * j as f64));
                let t1 = client
                    .submit(Job::RawApply { processor: "mesh8".into(), x: x.clone() })
                    .expect("submitted");
                let t2 = client
                    .submit(Job::RawApply { processor: "mesh8".into(), x: x.clone() })
                    .expect("submitted");
                for tk in [t2, t1] {
                    match tk.wait().expect("raw served") {
                        JobResult::RawApply { y } => {
                            assert!(baseline.matmul(&x).sub(&y).max_abs() < 1e-10);
                        }
                        other => panic!("unexpected raw result {other:?}"),
                    }
                }
            }
        }));
    }
    for th in threads {
        th.join().unwrap();
    }

    let client = RemoteClient::connect(&addr).expect("connect");

    // Reprogram over the wire versions the pooled mesh.
    let code: Vec<usize> = (0..n_code).map(|i| i % 6).collect();
    match client.submit_wait(Job::Reprogram { processor: "mesh8".into(), code }).unwrap() {
        JobResult::Reprogrammed { version } => assert_eq!(version, 2),
        other => panic!("unexpected {other:?}"),
    }

    // Compile over the wire: a 6×4 digital target on 2×2 tiles registers
    // a NEW processor into the live pool...
    let target = CMat::from_fn(6, 4, |i, j| C64::new(0.3 * i as f64 - 0.5, 0.1 * j as f64));
    let job = Job::Compile {
        name: "wire-virt".into(),
        target: target.clone(),
        tile: 2,
        fidelity: Fidelity::Digital,
    };
    match client.submit_wait(job).unwrap() {
        JobResult::Compiled { name, version, grid, tile, fidelity, fro_error, .. } => {
            assert_eq!(name, "wire-virt");
            assert_eq!(version, 1);
            assert_eq!(grid, (3, 2));
            assert_eq!(tile, 2);
            assert_eq!(fidelity, Fidelity::Digital);
            assert_eq!(fro_error, 0.0);
        }
        other => panic!("unexpected {other:?}"),
    }
    // ...which immediately serves RawApply traffic, matching a locally
    // compiled reference exactly (digital tiles are exact; the weights
    // also survived the wire bit-for-bit).
    let reference =
        VirtualProcessor::compile(&target, &PlanSpec::new(2, Fidelity::Digital)).unwrap();
    match client
        .submit_wait(Job::RawApply { processor: "wire-virt".into(), x: CMat::eye(4) })
        .unwrap()
    {
        JobResult::RawApply { y } => {
            assert!(LinearProcessor::matrix(&reference).sub(&y).max_abs() < 1e-12);
            assert!(target.sub(&y).max_abs() < 1e-12);
        }
        other => panic!("unexpected {other:?}"),
    }

    // Overload shedding is visible to remote callers AND in the metrics:
    // the stalled queue (depth 1, undrained) admits one job, sheds the next.
    let probe = || Job::RawApply { processor: "stall".into(), x: CMat::eye(2) };
    let first = client.submit(probe()).expect("first stalls in the queue");
    let second = client.submit(probe()).expect("submitted over the wire");
    let err = second.wait().expect_err("must be shed");
    assert!(err.to_string().contains("overloaded"), "{err}");
    // Drain the stalled queue so the first job completes.
    let h = stall_rx.recv().unwrap();
    let echo = match &h.job {
        Job::RawApply { x, .. } => x.clone(),
        other => panic!("unexpected stalled job {other:?}"),
    };
    h.respond(JobResult::RawApply { y: echo });
    match first.wait().expect("served after drain") {
        JobResult::RawApply { y } => assert_eq!((y.rows(), y.cols()), (2, 2)),
        other => panic!("unexpected {other:?}"),
    }

    // A v2 job inside a v4 envelope still decodes (compat shim) — sent
    // over a raw socket to exercise the server's shared decode path.
    {
        let mut raw = std::net::TcpStream::connect(&addr).unwrap();
        let envelope = concat!(
            r#"{"v":4,"id":1,"job":"#,
            r#"{"v":2,"kind":"classify","processor":"cls2x2","classifier":1,"point":[2,3]}}"#
        );
        write_frame(&mut raw, envelope.as_bytes()).unwrap();
        let payload = read_frame(&mut raw, MAX_FRAME).unwrap().expect("reply frame");
        match Response::decode(std::str::from_utf8(&payload).unwrap()).unwrap() {
            Response::Result { id, result: JobResult::Classify { .. } } => assert_eq!(id, 1),
            other => panic!("unexpected {other:?}"),
        }
        // Garbage on the same connection is answered (bad_request), not a
        // hang and not a crash.
        write_frame(&mut raw, b"certainly not json").unwrap();
        let payload = read_frame(&mut raw, MAX_FRAME).unwrap().expect("error frame");
        match Response::decode(std::str::from_utf8(&payload).unwrap()).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, "bad_request"),
            other => panic!("unexpected {other:?}"),
        }
    }

    // Admin plane: the registry lists the wire-compiled processor, health
    // is ok, and the metrics snapshot carries the transport counters.
    match client.admin(Admin::ListProcessors).unwrap() {
        AdminReply::Processors(list) => {
            let names: Vec<&str> = list.iter().map(|p| p.name.as_str()).collect();
            assert!(names.contains(&"wire-virt"), "{names:?}");
            assert!(names.contains(&"mnist8"));
            let mesh_info = list.iter().find(|p| p.name == "mesh8").unwrap();
            assert_eq!(mesh_info.version, 2, "reprogram bumped the pool version");
        }
        other => panic!("unexpected {other:?}"),
    }
    match client.admin(Admin::Health).unwrap() {
        AdminReply::Health { status, processors, shutting_down } => {
            assert_eq!(status, "ok");
            assert_eq!(processors, 5);
            assert!(!shutting_down);
        }
        other => panic!("unexpected {other:?}"),
    }
    match client.admin(Admin::MetricsSnapshot).unwrap() {
        AdminReply::Metrics(snap) => {
            let t = snap.get("transport").expect("transport counters in the snapshot");
            let get = |k: &str| t.get(k).and_then(|v| v.as_f64()).unwrap();
            assert!(get("connections_accepted") >= 5.0);
            assert!(get("frames_in") > 0.0);
            assert!(get("frames_out") > 0.0);
            assert!(get("decode_rejects") >= 1.0, "the garbage frame was counted");
            let shed = snap
                .get("jobs")
                .and_then(|j| j.get("raw_apply"))
                .and_then(|r| r.get("rejected"))
                .and_then(|v| v.as_f64())
                .unwrap();
            assert!(shed >= 1.0, "overload shed visible in the snapshot");
        }
        other => panic!("unexpected {other:?}"),
    }

    // In-process callers are untouched by the redesign: the same service
    // still answers typed submits directly.
    match svc
        .submit(Job::RawApply { processor: "wire-virt".into(), x: CMat::eye(4) })
        .expect("local submit through the live registry")
        .wait()
        .unwrap()
    {
        JobResult::RawApply { y } => assert!(target.sub(&y).max_abs() < 1e-12),
        other => panic!("unexpected {other:?}"),
    }

    // Wire-driven shutdown: acknowledged, then the accept loop exits.
    client.shutdown_server().expect("shutdown acknowledged");
    assert!(router.shutdown_requested());
    fe.wait_shutdown();
    fe.shutdown();
    let m = svc.metrics();
    assert!(m.job(JobKind::Compile).served.load(Ordering::Relaxed) >= 1);
}

/// PR-7 acceptance: cluster-scale sharded serving across REAL OS
/// processes. Three `rfnn serve --listen 127.0.0.1:0 --minimal` children
/// are deployed with a 3-shard × 2-replica layout; the scatter/gather
/// coordinator must answer bit-identically to a single-process compile,
/// keep answering the SAME bits after one node is killed mid-traffic
/// (failing over to each shard's surviving replica), and fail loudly —
/// never silently dropping rows — only when every replica is gone.
#[test]
fn cluster_sharded_serving_survives_replica_loss_across_processes() {
    use rfnn::compiler::{plan_shards, PlanSpec, VirtualProcessor};
    use rfnn::coordinator::sharded::{ShardConfig, ShardedProcessor};
    use rfnn::processor::{Fidelity, LinearProcessor};
    use std::io::BufRead;
    use std::process::{Child, Command, Stdio};
    use std::sync::atomic::Ordering;

    /// Spawn one bare serving node and parse its ephemeral address from
    /// the `listening on ADDR` banner (Rust's stdout is line-buffered
    /// even when piped, so the banner arrives as soon as the listener
    /// is up).
    fn spawn_node() -> (Child, String) {
        let mut child = Command::new(env!("CARGO_BIN_EXE_rfnn"))
            .args(["serve", "--listen", "127.0.0.1:0", "--minimal"])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn rfnn serve --minimal");
        let stdout = child.stdout.take().expect("piped stdout");
        let mut lines = std::io::BufReader::new(stdout).lines();
        let banner = lines.next().expect("banner line").expect("readable banner");
        let addr = banner
            .strip_prefix("listening on ")
            .unwrap_or_else(|| panic!("unexpected banner {banner:?}"))
            .trim()
            .to_string();
        // Keep draining so the child never blocks on a full pipe.
        std::thread::spawn(move || for _ in lines {});
        (child, addr)
    }

    let mut nodes: Vec<(Child, String)> = (0..3).map(|_| spawn_node()).collect();

    // One logical 12×9 Measured-fidelity processor in 3 shards; each
    // shard replicated on its own node plus the next one around the ring,
    // so killing any single node leaves every shard one live replica.
    let mut rng = Rng::new(0x7C1);
    let target = CMat::from_fn(12, 9, |_, _| C64::new(rng.normal(), rng.normal()));
    let spec = PlanSpec::new(2, Fidelity::Measured);
    let shards = plan_shards(&target, &spec, 3).expect("3-way tile-row split");
    let addrs: Vec<Vec<String>> =
        (0..3).map(|s| vec![nodes[s].1.clone(), nodes[(s + 1) % 3].1.clone()]).collect();
    let sp = ShardedProcessor::deploy("net", &shards, &addrs, ShardConfig::default())
        .expect("deploy over three child processes");

    // Sharded ≡ single-process, bit-for-bit (the acceptance pin).
    let full = VirtualProcessor::compile(&target, &spec).expect("local reference compile");
    let x = CMat::from_fn(9, 5, |_, _| C64::new(rng.normal(), rng.normal()));
    let before = sp.try_apply_batch(&x).expect("cluster apply");
    assert_eq!(before, LinearProcessor::apply_batch(&full, &x), "sharded must be bit-identical");

    // Kill one node mid-traffic. Shards 0 (preferred) and 2 (backup)
    // lose a replica; every answer must keep the exact same bits.
    nodes[0].0.kill().expect("kill node 0");
    nodes[0].0.wait().expect("reap node 0");
    let after = sp.try_apply_batch(&x).expect("failover must recover");
    assert_eq!(after, before, "zero wrong answers across a replica loss");
    let m = sp.cluster_metrics();
    let failovers: u64 =
        m.shards.iter().map(|s| s.failovers.load(Ordering::Relaxed)).sum();
    assert!(failovers > 0, "traffic must have rerouted to surviving replicas");
    assert_eq!(m.worst_health().name(), "degraded");
    // Recovery traffic: fresh batches still match the reference exactly.
    for k in 0..3 {
        let x = CMat::from_fn(9, 4, |i, j| {
            C64::new(0.1 * (i + k) as f64 - 0.3, 0.05 * j as f64)
        });
        let y = sp.try_apply_batch(&x).expect("degraded cluster still serves");
        assert_eq!(y, LinearProcessor::apply_batch(&full, &x), "batch {k}");
    }

    // With EVERY node gone the apply fails loudly: rows are never
    // silently zeroed or dropped.
    for (child, _) in nodes.iter_mut().skip(1) {
        child.kill().expect("kill node");
        child.wait().expect("reap node");
    }
    std::thread::sleep(std::time::Duration::from_millis(1100)); // let re-probe cooldowns lapse
    let err = sp.try_apply_batch(&x).expect_err("no replicas left").to_string();
    assert!(err.contains("lost"), "{err}");
}

/// Shared-secret transport auth (PR-7 satellite): a token-configured
/// server refuses wrong or missing first-frame tokens (counted in the
/// transport metrics), serves token-bearing clients normally, and an
/// OPEN server ignores a stray auth frame — so token-bearing clients
/// interoperate with tokenless nodes. Tokens are passed explicitly
/// (never via `set_var`: tests run in parallel).
#[test]
fn cluster_transport_auth_gates_connections() {
    use rfnn::coordinator::router::{Admin, AdminReply, Router};
    use rfnn::coordinator::service::{ProcessorPool, ProcessorService};
    use rfnn::coordinator::transport::{RemoteClient, TcpConfig, TcpFrontEnd};
    use std::sync::atomic::Ordering;
    use std::sync::Arc;

    let svc = Arc::new(ProcessorService::new(ProcessorPool::new()));
    let router = Arc::new(Router::new(svc));
    let cfg = TcpConfig { auth_token: Some("sesame".into()), ..TcpConfig::default() };
    let fe = TcpFrontEnd::bind("127.0.0.1:0", router.clone(), cfg).expect("bind with token");
    let addr = fe.local_addr().to_string();

    // The right token serves.
    let ok = RemoteClient::connect_with(&addr, Some("sesame")).expect("connect");
    match ok.admin(Admin::Health).expect("authed admin") {
        AdminReply::Health { status, .. } => assert_eq!(status, "ok"),
        other => panic!("unexpected {other:?}"),
    }
    // A wrong token and a missing token are both refused: the first
    // request fails with the connection-scope `unauthorized` error.
    let wrong = RemoteClient::connect_with(&addr, Some("open-up")).expect("tcp connects");
    let err = wrong.admin(Admin::Health).expect_err("wrong token refused").to_string();
    assert!(err.contains("unauthorized"), "{err}");
    let missing = RemoteClient::connect_with(&addr, None).expect("tcp connects");
    let err = missing.admin(Admin::Health).expect_err("missing token refused").to_string();
    assert!(err.contains("unauthorized"), "{err}");
    let rejects = router.metrics().transport.auth_rejects.load(Ordering::Relaxed);
    assert!(rejects >= 2, "both refusals are counted, got {rejects}");

    // An open server ignores a stray auth frame: token-bearing clients
    // interoperate with tokenless nodes.
    let svc = Arc::new(ProcessorService::new(ProcessorPool::new()));
    let open_router = Arc::new(Router::new(svc));
    let open = TcpFrontEnd::bind("127.0.0.1:0", open_router, TcpConfig::default())
        .expect("bind open");
    let chatty = RemoteClient::connect_with(&open.local_addr().to_string(), Some("sesame"))
        .expect("connect");
    match chatty.admin(Admin::Health).expect("open server serves") {
        AdminReply::Health { status, .. } => assert_eq!(status, "ok"),
        other => panic!("unexpected {other:?}"),
    }
}

/// PR-10 acceptance (the `soak-smoke` CI gate): the reactor front end
/// survives 200 concurrent loopback clients driving mixed traffic —
/// pipelined out-of-order submits, deferred poll-mode multiplexing, and
/// classify/raw-apply jobs — on a bounded thread budget. Afterwards the
/// metrics snapshot must show every connection accepted, zero decode
/// rejects (no wire drift under concurrency), zero stuck tickets, and
/// exactly `workers + 1` reactor threads regardless of client count.
#[test]
fn soak_reactor_front_end_serves_200_concurrent_clients() {
    use rfnn::coordinator::batcher::BatchPolicy;
    use rfnn::coordinator::router::{Admin, AdminReply, Router};
    use rfnn::coordinator::service::{
        Job, JobResult, PoolConfig, ProcessorPool, ProcessorService, Workload,
    };
    use rfnn::coordinator::transport::{RemoteClient, TcpConfig, TcpFrontEnd};
    use rfnn::processor::LinearProcessor;
    use std::sync::Arc;
    use std::time::Duration;

    let models = rfnn::cli::demo_classifiers();
    let mesh = DiscreteMesh::new(8, MeshBackend::Ideal);
    let baseline = LinearProcessor::matrix(&mesh).clone();
    let cfg = PoolConfig {
        batch: BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(1) },
        ..PoolConfig::default()
    };
    let pool = ProcessorPool::new();
    pool.register("cls2x2", Workload::Classify2x2(models.clone()), cfg).unwrap();
    pool.register("mesh8", Workload::Processor(Box::new(mesh)), cfg).unwrap();
    let svc = Arc::new(ProcessorService::new(pool));
    let router = Arc::new(Router::new(svc));
    let tcp = TcpConfig { max_connections: 512, workers: 4, ..TcpConfig::default() };
    let fe = TcpFrontEnd::bind("127.0.0.1:0", router.clone(), tcp).expect("bind");
    let addr = fe.local_addr().to_string();

    const CLIENTS: usize = 200;
    let mut threads = Vec::new();
    for t in 0..CLIENTS {
        let addr = addr.clone();
        let models = models.clone();
        let baseline = baseline.clone();
        threads.push(std::thread::spawn(move || {
            let client = RemoteClient::connect(&addr).expect("connect");
            let dev = rfnn::nn::rfnn2x2::ideal_device();
            let x =
                CMat::from_fn(8, 2, |i, j| C64::new(0.1 * i as f64, 0.05 * (j + t % 3) as f64));
            // Pipelined submits resolve out of order (demuxed by id)...
            let t1 = client
                .submit(Job::RawApply { processor: "mesh8".into(), x: x.clone() })
                .expect("submitted");
            let t2 = client
                .submit(Job::RawApply { processor: "mesh8".into(), x: x.clone() })
                .expect("submitted");
            // ...alongside a deferred submit whose reply is a ticket,
            // resolved by polling the SAME connection.
            let ticket = client
                .submit_deferred(Job::RawApply { processor: "mesh8".into(), x: x.clone() })
                .expect("deferred");
            for tk in [t2, t1] {
                match tk.wait().expect("raw served") {
                    JobResult::RawApply { y } => {
                        assert!(baseline.matmul(&x).sub(&y).max_abs() < 1e-10);
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
            match client.wait_ticket(ticket).expect("deferred job resolves") {
                JobResult::RawApply { y } => {
                    assert!(baseline.matmul(&x).sub(&y).max_abs() < 1e-10);
                }
                other => panic!("unexpected {other:?}"),
            }
            // Polling a bogus ticket errors cleanly, not wedging the wire.
            let err = client
                .poll_ticket(ticket.wrapping_add(0x5AFE_0000))
                .expect_err("bogus tickets refuse")
                .to_string();
            assert!(err.contains("unknown_ticket"), "{err}");
            let classifier = t % 6;
            let point = [(t % 9) as f64, 12.0 - (t % 7) as f64];
            match client
                .submit_wait(Job::Classify { processor: "cls2x2".into(), classifier, point })
                .expect("classify served")
            {
                JobResult::Classify { yhat, .. } => {
                    let want = models[classifier].forward(&dev, point);
                    assert!((yhat - want).abs() < 1e-9);
                }
                other => panic!("unexpected {other:?}"),
            }
        }));
    }
    for th in threads {
        th.join().unwrap();
    }

    // The snapshot pins the soak contract.
    let admin = RemoteClient::connect(&addr).expect("connect");
    match admin.admin(Admin::MetricsSnapshot).unwrap() {
        AdminReply::Metrics(snap) => {
            let t = snap.get("transport").expect("transport counters");
            let get = |k: &str| t.get(k).and_then(|v| v.as_f64()).unwrap();
            assert!(get("connections_accepted") >= (CLIENTS + 1) as f64);
            assert_eq!(get("connections_refused"), 0.0);
            assert_eq!(get("decode_rejects"), 0.0, "no decode-reject drift");
            assert_eq!(get("auth_rejects"), 0.0);
            assert_eq!(get("reactor_threads"), 5.0, "4 workers + 1 reactor, always");
            assert_eq!(
                snap.get("tickets_pending").and_then(|v| v.as_f64()),
                Some(0.0),
                "no stuck tickets after the soak"
            );
            let polls = snap
                .get("jobs")
                .and_then(|j| j.get("poll"))
                .and_then(|p| p.get("served"))
                .and_then(|v| v.as_f64())
                .unwrap();
            assert!(polls >= CLIENTS as f64, "every client polled at least once, got {polls}");
        }
        other => panic!("unexpected {other:?}"),
    }
    drop(admin);
    fe.shutdown();
}

/// Reactor regression: a client that disconnects with replies still in
/// flight must not leak its tickets — the reactor reaps them on EOF, so
/// the pending-ticket gauge returns to zero and the stalled worker's
/// late replies fall on forgotten tickets harmlessly (the old transport
/// leaked one parked waiter thread per abandoned job here).
#[test]
fn soak_disconnect_mid_flight_reaps_tracked_tickets() {
    use rfnn::coordinator::metrics::JobKind;
    use rfnn::coordinator::router::{Admin, AdminReply, Router};
    use rfnn::coordinator::service::{
        Job, JobResult, PoolConfig, ProcessorPool, ProcessorService,
    };
    use rfnn::coordinator::transport::{RemoteClient, TcpConfig, TcpFrontEnd};
    use rfnn::processor::Fidelity;
    use std::sync::Arc;
    use std::time::Duration;

    let pool = ProcessorPool::new();
    let stall_rx = pool
        .register_external(
            "stall",
            (2, 2),
            Fidelity::Digital,
            &[JobKind::RawApply],
            PoolConfig { queue_depth: 4, ..PoolConfig::default() },
        )
        .unwrap();
    let svc = Arc::new(ProcessorService::new(pool));
    let router = Arc::new(Router::new(svc));
    let fe = TcpFrontEnd::bind("127.0.0.1:0", router.clone(), TcpConfig::default()).unwrap();
    let addr = fe.local_addr().to_string();

    let client = RemoteClient::connect(&addr).expect("connect");
    let t1 =
        client.submit(Job::RawApply { processor: "stall".into(), x: CMat::eye(2) }).unwrap();
    let t2 =
        client.submit(Job::RawApply { processor: "stall".into(), x: CMat::eye(2) }).unwrap();
    // Wait until both jobs are admitted and tracked server-side...
    for _ in 0..400 {
        if router.tickets_pending() >= 2 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(router.tickets_pending() >= 2, "jobs admitted and tracked");
    // ...then vanish without collecting either reply.
    drop(t1);
    drop(t2);
    drop(client);
    for _ in 0..400 {
        if router.tickets_pending() == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(router.tickets_pending(), 0, "disconnect must reap tracked tickets");
    // The stalled worker answers into the void: harmless.
    for _ in 0..2 {
        let h = stall_rx.recv().unwrap();
        let echo = match &h.job {
            Job::RawApply { x, .. } => x.clone(),
            other => panic!("unexpected stalled job {other:?}"),
        };
        h.respond(JobResult::RawApply { y: echo });
    }
    // The reactor is still healthy: a fresh client gets served.
    let probe = RemoteClient::connect(&addr).expect("reconnect");
    match probe.admin(Admin::Health).unwrap() {
        AdminReply::Health { status, .. } => assert_eq!(status, "ok"),
        other => panic!("unexpected {other:?}"),
    }
    fe.shutdown();
}

/// Hostile connection: a slow-loris client dribbling a frame one byte at
/// a time must neither wedge the reactor nor corrupt framing — the
/// partial frame assembles across sweeps and is answered, while a
/// well-behaved client opened mid-crawl is served immediately.
#[test]
fn soak_slow_loris_partial_frames_assemble_without_wedging() {
    use rfnn::coordinator::router::{Admin, AdminReply, Router};
    use rfnn::coordinator::service::{ProcessorPool, ProcessorService};
    use rfnn::coordinator::transport::{
        read_frame, write_frame, RemoteClient, Response, TcpConfig, TcpFrontEnd, MAX_FRAME,
    };
    use std::io::Write;
    use std::sync::Arc;
    use std::time::Duration;

    let svc = Arc::new(ProcessorService::new(ProcessorPool::new()));
    let router = Arc::new(Router::new(svc));
    let fe = TcpFrontEnd::bind("127.0.0.1:0", router, TcpConfig::default()).unwrap();
    let addr = fe.local_addr().to_string();

    let mut loris = std::net::TcpStream::connect(&addr).unwrap();
    loris.set_nodelay(true).ok();
    let mut framed = Vec::new();
    write_frame(&mut framed, br#"{"v":4,"id":9,"admin":{"v":4,"admin":"health"}}"#).unwrap();
    let (head, tail) = framed.split_at(framed.len() / 2);
    let dribble = |sock: &mut std::net::TcpStream, bytes: &[u8]| {
        for b in bytes {
            sock.write_all(std::slice::from_ref(b)).expect("loris byte");
            sock.flush().ok();
            std::thread::sleep(Duration::from_millis(2));
        }
    };
    dribble(&mut loris, head);
    // Mid-frame, a well-behaved client is served: one stalled read never
    // blocks the event loop.
    let ok = RemoteClient::connect(&addr).expect("connect");
    match ok.admin(Admin::Health).expect("served while the loris crawls") {
        AdminReply::Health { status, .. } => assert_eq!(status, "ok"),
        other => panic!("unexpected {other:?}"),
    }
    dribble(&mut loris, tail);
    // The dribbled frame assembled and was answered.
    let payload = read_frame(&mut loris, MAX_FRAME).unwrap().expect("loris reply");
    match Response::decode(std::str::from_utf8(&payload).unwrap()).unwrap() {
        Response::AdminReply { id, reply: AdminReply::Health { status, .. } } => {
            assert_eq!(id, 9);
            assert_eq!(status, "ok");
        }
        other => panic!("unexpected {other:?}"),
    }
    fe.shutdown();
}

/// Hostile connection: a client that never reads its replies cannot pin
/// reactor memory — once its pending reply bytes exceed the configured
/// write-buffer cap the connection is shed, and the reactor keeps
/// serving everyone else.
#[test]
fn soak_never_reading_client_is_shed_at_the_write_buffer_cap() {
    use rfnn::coordinator::batcher::BatchPolicy;
    use rfnn::coordinator::router::{Admin, AdminReply, Router};
    use rfnn::coordinator::service::{
        Job, PoolConfig, ProcessorPool, ProcessorService, Workload,
    };
    use rfnn::coordinator::transport::{
        write_frame, RemoteClient, Request, TcpConfig, TcpFrontEnd,
    };
    use std::io::{Read, Write};
    use std::sync::Arc;
    use std::time::Duration;

    let cfg = PoolConfig {
        batch: BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(1) },
        ..PoolConfig::default()
    };
    let pool = ProcessorPool::new();
    pool.register(
        "mesh8",
        Workload::Processor(Box::new(DiscreteMesh::new(8, MeshBackend::Ideal))),
        cfg,
    )
    .unwrap();
    let svc = Arc::new(ProcessorService::new(pool));
    let router = Arc::new(Router::new(svc));
    let tcp = TcpConfig { write_buffer_cap: 8 * 1024, ..TcpConfig::default() };
    let fe = TcpFrontEnd::bind("127.0.0.1:0", router, tcp).unwrap();
    let addr = fe.local_addr().to_string();

    // Pump sizable raw-apply jobs and never read a single reply: the
    // replies clog the OS buffers, then the server-side write buffer,
    // then the cap trips and the server closes on us.
    let mut sink = std::net::TcpStream::connect(&addr).unwrap();
    let x = CMat::from_fn(8, 16, |i, j| C64::new(0.25 * i as f64 - 1.0, 0.125 * j as f64));
    let mut shed = false;
    let mut framed = Vec::new();
    for id in 1..=4000u64 {
        framed.clear();
        let req = Request::Job {
            id,
            job: Job::RawApply { processor: "mesh8".into(), x: x.clone() },
            trace: None,
            defer: false,
        };
        write_frame(&mut framed, req.encode().as_bytes()).unwrap();
        if sink.write_all(&framed).is_err() {
            shed = true;
            break;
        }
    }
    if !shed {
        // The close may still be in flight: drain until EOF/reset shows
        // up (a timeout means we were never disconnected — a failure).
        sink.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let mut buf = [0u8; 4096];
        loop {
            match sink.read(&mut buf) {
                Ok(0) => {
                    shed = true;
                    break;
                }
                Ok(_) => {}
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    break;
                }
                Err(_) => {
                    shed = true;
                    break;
                }
            }
        }
    }
    assert!(shed, "a never-reading client must be disconnected at the cap");
    // The reactor survived the hostile connection: fresh traffic serves.
    let probe = RemoteClient::connect(&addr).expect("reconnect");
    match probe.admin(Admin::Health).unwrap() {
        AdminReply::Health { status, .. } => assert_eq!(status, "ok"),
        other => panic!("unexpected {other:?}"),
    }
    fe.shutdown();
}

/// PR-8 acceptance: ONE traced sharded request produces ONE stitched
/// trace across REAL OS processes. Two `rfnn serve --minimal` children
/// each serve a shard; the coordinator's `scatter`/`gather` spans and the
/// children's `server.request` → `frame.decode`/`queue.wait`/`exec`
/// spans — shipped back in the response envelopes and adopted with a
/// `node` tag — all share the client's trace id, with every remote root
/// hanging under the coordinator scatter span that carried it.
#[test]
fn cluster_trace_stitches_across_processes() {
    use rfnn::compiler::{plan_shards, PlanSpec};
    use rfnn::coordinator::sharded::{ShardConfig, ShardedProcessor};
    use rfnn::obs::trace::{with_current, Policy, TraceCtx};
    use rfnn::processor::Fidelity;
    use rfnn::util::json::Json;
    use std::io::BufRead;
    use std::process::{Child, Command, Stdio};

    /// Spawn one bare serving node with every trace retained, and parse
    /// its ephemeral address from the `listening on ADDR` banner.
    fn spawn_node() -> (Child, String) {
        let mut child = Command::new(env!("CARGO_BIN_EXE_rfnn"))
            .args(["serve", "--listen", "127.0.0.1:0", "--minimal"])
            .env("RFNN_TRACE", "all")
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn rfnn serve --minimal");
        let stdout = child.stdout.take().expect("piped stdout");
        let mut lines = std::io::BufReader::new(stdout).lines();
        let banner = lines.next().expect("banner line").expect("readable banner");
        let addr = banner
            .strip_prefix("listening on ")
            .unwrap_or_else(|| panic!("unexpected banner {banner:?}"))
            .trim()
            .to_string();
        std::thread::spawn(move || for _ in lines {});
        (child, addr)
    }

    let mut nodes: Vec<(Child, String)> = (0..2).map(|_| spawn_node()).collect();

    // One logical 8×6 processor split across the two child processes.
    let mut rng = Rng::new(0xABE);
    let target = CMat::from_fn(8, 6, |_, _| C64::new(rng.normal(), rng.normal()));
    let spec = PlanSpec::new(2, Fidelity::Measured);
    let shards = plan_shards(&target, &spec, 2).expect("2-way tile-row split");
    let addrs: Vec<Vec<String>> = (0..2).map(|s| vec![nodes[s].1.clone()]).collect();
    let sp = ShardedProcessor::deploy("tr", &shards, &addrs, ShardConfig::default())
        .expect("deploy over two child processes");

    let x = CMat::from_fn(6, 3, |_, _| C64::new(rng.normal(), rng.normal()));
    let ctx = TraceCtx::start_with(Policy::All, "client.request").expect("All always traces");
    let y = with_current(&ctx, ctx.root(), || sp.try_apply_batch(&x)).expect("cluster apply");
    assert_eq!((y.rows(), y.cols()), (8, 3));
    let payload = ctx.finish(true).expect("exported");

    // ONE stitched trace: every span — local and adopted — carries the
    // client's trace id.
    let spans = payload.get("spans").unwrap().as_arr().unwrap();
    let tid = ctx.trace_id() as f64;
    for s in spans {
        assert_eq!(s.get("trace").unwrap().as_f64(), Some(tid), "foreign trace id in {s:?}");
    }
    // The coordinator's side: per-shard scatter and gather.
    let names: Vec<&str> =
        spans.iter().filter_map(|s| s.get("name").and_then(Json::as_str)).collect();
    for want in ["scatter.s0", "scatter.s1", "gather.s0", "gather.s1"] {
        assert!(names.contains(&want), "missing {want} in {names:?}");
    }
    // The children's side: one adopted, node-tagged server root per
    // shard process, each parented under a coordinator scatter span.
    let remote_roots: Vec<&Json> = spans
        .iter()
        .filter(|s| {
            s.get("node").is_some()
                && s.get("name").and_then(Json::as_str) == Some("server.request")
        })
        .collect();
    assert_eq!(remote_roots.len(), 2, "one remote root per shard process");
    let scatter_ids: Vec<f64> = spans
        .iter()
        .filter(|s| {
            matches!(s.get("name").and_then(Json::as_str),
                     Some(n) if n.starts_with("scatter."))
        })
        .map(|s| s.get("id").unwrap().as_f64().unwrap())
        .collect();
    for s in &remote_roots {
        let node = s.get("node").unwrap().as_str().unwrap();
        assert!(node == nodes[0].1 || node == nodes[1].1, "unknown node tag {node}");
        let parent = s.get("parent").unwrap().as_f64().unwrap();
        assert!(
            scatter_ids.contains(&parent),
            "remote root parented to {parent}, scatters {scatter_ids:?}"
        );
    }
    // Node-internal stages crossed the wire too: transport decode, queue
    // wait, and the worker's execution span.
    for want in ["frame.decode", "queue.wait", "exec"] {
        assert!(
            spans.iter().any(|s| {
                s.get("node").is_some()
                    && s.get("name").and_then(Json::as_str) == Some(want)
            }),
            "missing remote {want} span"
        );
    }

    for (child, _) in nodes.iter_mut() {
        child.kill().expect("kill node");
        child.wait().expect("reap node");
    }
}

/// Property: any mesh program applied to the standard basis reconstructs
/// exactly the columns of its matrix.
#[test]
fn mesh_program_matrix_column_property() {
    forall("program columns", 20, |g| {
        let n = g.usize_in(2, 6);
        let a = CMat::from_fn(n, n, |_, _| C64::new(g.normal(), g.normal()));
        let f = rfnn::math::svd::svd(&a);
        let u = f.u.matmul(&f.vh);
        let prog = decompose_unitary(&u);
        let m = prog.matrix();
        let col = g.usize_in(0, n - 1);
        let mut e = vec![C64::ZERO; n];
        e[col] = C64::ONE;
        let y = prog.apply(&e);
        for i in 0..n {
            assert!((y[i] - m[(i, col)]).abs() < 1e-10);
        }
    });
}
