#!/usr/bin/env python3
"""Perf regression gate for the BENCH_pr*.json trajectory.

Compares the current run's bench records against the previous successful
run's `bench-json` artifact (downloaded by the workflow into --baseline),
falling back to the committed BENCH_baseline.json manifest when no prior
artifact exists (first run on a fresh branch/fork). Entries are matched
per bench file by their identifying fields (kernel/mode/n/batch/tile) and
every latency field (`*ns_per*` / `*_ns`) is compared; any entry more than
THRESHOLD slower than baseline fails the gate.

Baselines below --min-ns are skipped: sub-microsecond micro-bench medians
on shared CI runners are noise-dominated and would make a hard gate flap.
"""

import argparse
import glob
import json
import os
import sys

KEY_FIELDS = ("kernel", "mode", "n", "batch", "tile")


def entry_key(entry):
    return tuple((k, entry[k]) for k in KEY_FIELDS if k in entry)


def is_latency(name):
    return "ns_per" in name or name.endswith("_ns")


def load(path):
    with open(path) as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True, help="dir with this run's BENCH_pr*.json")
    ap.add_argument("--baseline", default=None, help="dir with the prior run's artifact")
    ap.add_argument("--manifest", default=None, help="committed fallback manifest")
    ap.add_argument("--threshold", type=float, default=0.20)
    ap.add_argument("--min-ns", type=float, default=1000.0)
    args = ap.parse_args()

    manifest = {}
    if args.manifest and os.path.exists(args.manifest):
        manifest = load(args.manifest).get("benches", {})

    current = sorted(glob.glob(os.path.join(args.current, "BENCH_pr*.json")))
    if not current:
        print(f"perf-gate: no BENCH_pr*.json found in {args.current}")
        return 1

    regressions = []
    compared = 0
    skipped = []
    for path in current:
        name = os.path.basename(path)
        cur = load(path)
        base = None
        if args.baseline:
            bp = os.path.join(args.baseline, name)
            if os.path.exists(bp):
                base = load(bp)
        if base is None:
            base = manifest.get(name)
        if base is None:
            skipped.append(name)
            continue
        base_by_key = {entry_key(e): e for e in base.get("results", [])}
        for entry in cur.get("results", []):
            b = base_by_key.get(entry_key(entry))
            if b is None:
                skipped.append(f"{name}:{entry_key(entry)}")
                continue
            for field, value in entry.items():
                if not is_latency(field) or not isinstance(value, (int, float)):
                    continue
                bv = b.get(field)
                if not isinstance(bv, (int, float)) or bv < args.min_ns:
                    continue
                compared += 1
                ratio = value / bv
                line = f"{name} {entry_key(entry)} {field}: {bv:.0f} -> {value:.0f} ns ({ratio:.2f}x)"
                if ratio > 1.0 + args.threshold:
                    regressions.append(line)
                    print(f"REGRESSION  {line}")
                else:
                    print(f"ok          {line}")
    for s in skipped:
        print(f"no-baseline {s}")
    print(
        f"perf-gate: {compared} comparisons, {len(regressions)} regressions "
        f"(threshold +{args.threshold:.0%}), {len(skipped)} skipped"
    )
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
