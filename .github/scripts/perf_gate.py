#!/usr/bin/env python3
"""Perf regression gate for the BENCH_pr*.json trajectory.

Compares the current run's bench records against the `bench-json`
artifacts of the last N successful runs (each downloaded by the workflow
into its own dir, passed as repeated --baseline flags). For every
(bench file, entry key, latency field) the baseline is the MEDIAN across
those runs, so one anomalously fast or slow prior run on a shared CI
machine cannot set the bar by itself. When no prior artifact exists
(first run on a fresh branch/fork) the committed BENCH_baseline.json
manifest is the fallback.

Entries are matched per bench file by their identifying fields
(kernel/mode/n/batch/tile) and every latency field (`*ns_per*` / `*_ns`)
is compared. The allowed slowdown is per-bench: the manifest's
"thresholds" map gives each BENCH_pr*.json its own bar (noisier
end-to-end benches get more headroom than tight kernel loops), with its
"default" entry — or --threshold — covering files the map doesn't name.

Baselines below --min-ns are skipped: sub-microsecond micro-bench medians
on shared CI runners are noise-dominated and would make a hard gate flap.
"""

import argparse
import glob
import json
import os
import statistics
import sys

KEY_FIELDS = ("kernel", "mode", "n", "batch", "tile")


def entry_key(entry):
    return tuple((k, entry[k]) for k in KEY_FIELDS if k in entry)


def is_latency(name):
    return "ns_per" in name or name.endswith("_ns")


def load(path):
    with open(path) as f:
        return json.load(f)


def median_baseline(baseline_dirs, name):
    """Per-(entry key, field) median across every baseline run that has
    this bench file. Returns {key: {field: ns}} or None if no run has it."""
    runs = []
    for d in baseline_dirs:
        bp = os.path.join(d, name)
        if os.path.exists(bp):
            try:
                runs.append(load(bp))
            except (OSError, json.JSONDecodeError) as e:
                print(f"perf-gate: ignoring unreadable baseline {bp}: {e}")
    if not runs:
        return None
    merged = {}
    for run in runs:
        for entry in run.get("results", []):
            slot = merged.setdefault(entry_key(entry), {})
            for field, value in entry.items():
                if is_latency(field) and isinstance(value, (int, float)):
                    slot.setdefault(field, []).append(value)
    return {
        key: {field: statistics.median(vals) for field, vals in fields.items()}
        for key, fields in merged.items()
    }


def manifest_baseline(manifest_benches, name):
    """Adapt a manifest bench record to the {key: {field: ns}} shape."""
    rec = manifest_benches.get(name)
    if rec is None:
        return None
    out = {}
    for entry in rec.get("results", []):
        out[entry_key(entry)] = {
            field: value
            for field, value in entry.items()
            if is_latency(field) and isinstance(value, (int, float))
        }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True, help="dir with this run's BENCH_pr*.json")
    ap.add_argument(
        "--baseline",
        action="append",
        default=[],
        help="dir with one prior run's artifact (repeat for median-of-N)",
    )
    ap.add_argument("--manifest", default=None, help="committed fallback manifest")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.20,
        help="fallback slowdown bar when the manifest thresholds map has no entry",
    )
    ap.add_argument("--min-ns", type=float, default=1000.0)
    args = ap.parse_args()

    manifest_benches, thresholds = {}, {}
    if args.manifest and os.path.exists(args.manifest):
        m = load(args.manifest)
        manifest_benches = m.get("benches", {})
        thresholds = m.get("thresholds", {})
    default_threshold = thresholds.get("default", args.threshold)

    current = sorted(glob.glob(os.path.join(args.current, "BENCH_pr*.json")))
    if not current:
        print(f"perf-gate: no BENCH_pr*.json found in {args.current}")
        return 1

    baseline_dirs = [d for d in args.baseline if os.path.isdir(d)]
    print(f"perf-gate: {len(baseline_dirs)} baseline run(s): {baseline_dirs}")

    regressions = []
    compared = 0
    skipped = []
    for path in current:
        name = os.path.basename(path)
        cur = load(path)
        threshold = thresholds.get(name, default_threshold)
        base_by_key = median_baseline(baseline_dirs, name)
        if base_by_key is None:
            base_by_key = manifest_baseline(manifest_benches, name)
        if base_by_key is None:
            skipped.append(name)
            continue
        for entry in cur.get("results", []):
            b = base_by_key.get(entry_key(entry))
            if b is None:
                skipped.append(f"{name}:{entry_key(entry)}")
                continue
            for field, value in entry.items():
                if not is_latency(field) or not isinstance(value, (int, float)):
                    continue
                bv = b.get(field)
                if not isinstance(bv, (int, float)) or bv < args.min_ns:
                    continue
                compared += 1
                ratio = value / bv
                line = (
                    f"{name} {entry_key(entry)} {field}: "
                    f"{bv:.0f} -> {value:.0f} ns ({ratio:.2f}x, bar +{threshold:.0%})"
                )
                if ratio > 1.0 + threshold:
                    regressions.append(line)
                    print(f"REGRESSION  {line}")
                else:
                    print(f"ok          {line}")
    for s in skipped:
        print(f"no-baseline {s}")
    print(
        f"perf-gate: {compared} comparisons, {len(regressions)} regressions, "
        f"{len(skipped)} skipped"
    )
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
