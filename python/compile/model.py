"""Layer-2 JAX model: the 4-layer MNIST RFNN forward pass (Fig. 14).

    x[B, 784] -> Dense(784, N) -> leaky-ReLU
              -> N x N analog mesh + |.| detection   (L1 Pallas kernel)
              -> Dense(N, 10) -> softmax

The mesh coefficients are *runtime inputs* (not baked weights): the rust
coordinator recomputes the six (C, N) planes whenever DSPSA changes the
device states and feeds them with each request batch, exactly as the
physical device would be re-biased. Python never runs on the request path;
this module exists to be lowered once by `aot.py`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.mesh import mesh_abs, mesh_abs_dense

LEAKY_ALPHA = 0.01


def leaky_relu(x, alpha: float = LEAKY_ALPHA):
    return jnp.where(x >= 0.0, x, alpha * x)


def rfnn_forward(x, w1, b1, coeffs, w2, b2):
    """Full forward pass -> class probabilities.

    Args:
      x:  f32[B, 784] input images.
      w1: f32[N, 784], b1: f32[N]   -- digital Dense-1.
      coeffs: six f32[C, N] planes  -- analog mesh (re/im A/B/C).
      w2: f32[10, N], b2: f32[10]   -- digital Dense-2.
    Returns:
      f32[B, 10] softmax probabilities.
    """
    a1 = leaky_relu(x @ w1.T + b1)
    h2 = mesh_abs(a1, coeffs)
    logits = h2 @ w2.T + b2
    return jax.nn.softmax(logits, axis=-1)


def rfnn_logits(x, w1, b1, coeffs, w2, b2):
    """Forward pass up to logits (for losses computed elsewhere)."""
    a1 = leaky_relu(x @ w1.T + b1)
    h2 = mesh_abs(a1, coeffs)
    return h2 @ w2.T + b2


def mesh_abs_only(x, coeffs):
    """Just the analog stage: |mesh @ x| (exported for the serving path
    that drives the analog block directly)."""
    return mesh_abs(x, coeffs)


def rfnn_forward_dense(x, w1, b1, m_re, m_im, w2, b2):
    """Serving-path forward: the mesh stage uses the precomposed matrix
    (see kernels.mesh.mesh_abs_dense — the #Perf L1 optimization). The
    coordinator recomputes (m_re, m_im) from the device states whenever
    DSPSA re-biases the mesh."""
    a1 = leaky_relu(x @ w1.T + b1)
    h2 = mesh_abs_dense(a1, m_re, m_im)
    logits = h2 @ w2.T + b2
    return jax.nn.softmax(logits, axis=-1)


def mesh_abs_dense_only(x, m_re, m_im):
    """Just the analog stage, dense variant."""
    return mesh_abs_dense(x, m_re, m_im)


def reference_forward_np(x, w1, b1, n, columns, w2, b2):
    """Numpy reference of the full forward (dense mesh matrix), for tests."""
    import numpy as np

    from .kernels.ref import columns_to_matrix

    a1 = np.asarray(x) @ np.asarray(w1).T + np.asarray(b1)
    a1 = np.where(a1 >= 0.0, a1, LEAKY_ALPHA * a1)
    m = columns_to_matrix(n, columns)
    h2 = np.abs(a1.astype(np.complex64) @ m.T)
    logits = h2 @ np.asarray(w2).T + np.asarray(b2)
    e = np.exp(logits - logits.max(axis=-1, keepdims=True))
    return e / e.sum(axis=-1, keepdims=True)
