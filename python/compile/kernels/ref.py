"""Pure-jnp/numpy oracles for the Pallas mesh kernel.

Two independent references:
  * `mesh_abs_ref` -- same column-sweep algorithm in plain jnp complex64
    (checks the re/im-plane arithmetic and the roll encoding);
  * `mesh_abs_dense_ref` -- composes the full NxN complex matrix from the
    columns and applies it as one matmul (checks the *algorithm* against
    straight linear algebra, mirroring rust's DiscreteMesh::matrix()).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def mesh_abs_ref(x, coeffs):
    """Column sweep in complex arithmetic: |mesh @ x| for f32[B, N] x."""
    ar, ai, br, bi, cr, ci = (jnp.asarray(p) for p in coeffs)
    a = ar + 1j * ai
    b = br + 1j * bi
    c = cr + 1j * ci
    z = x.astype(jnp.complex64)
    for k in range(a.shape[0]):
        z = a[k] * z + b[k] * jnp.roll(z, -1, axis=1) + c[k] * jnp.roll(z, 1, axis=1)
    return jnp.abs(z).astype(jnp.float32)


def columns_to_matrix(n: int, columns):
    """Compose the dense NxN complex transfer matrix from (p, t) columns."""
    m = np.eye(n, dtype=np.complex64)
    for col in columns:
        step = np.eye(n, dtype=np.complex64)
        for p, t in col:
            t = np.asarray(t, np.complex64)
            step[p, p] = t[0, 0]
            step[p, p + 1] = t[0, 1]
            step[p + 1, p] = t[1, 0]
            step[p + 1, p + 1] = t[1, 1]
        m = step @ m
    return m


def mesh_abs_dense_ref(x, n: int, columns):
    """|M @ x| with M composed densely (independent of the roll encoding)."""
    m = columns_to_matrix(n, columns)
    z = np.asarray(x, np.complex64) @ m.T
    return np.abs(z).astype(np.float32)


def random_unitary_2x2(rng: np.random.Generator):
    """A Haar-ish random U(2) via the device parameterization t(theta, phi)."""
    theta = rng.uniform(0.0, np.pi)
    phi = rng.uniform(0.0, 2.0 * np.pi)
    c = 1j * np.exp(-0.5j * theta)
    s, co = np.sin(theta / 2.0), np.cos(theta / 2.0)
    e = np.exp(-1j * phi)
    return np.array([[e * s, e * co], [co, -s]], np.complex64) * c


def random_columns(n: int, rng: np.random.Generator, density: float = 1.0):
    """Random mesh columns on the Reck layout (optionally sparsified)."""
    from ..kernels.mesh import reck_columns

    cols = []
    for ps in reck_columns(n):
        col = []
        for p in ps:
            if rng.uniform() <= density:
                col.append((p, random_unitary_2x2(rng)))
        cols.append(col)
    return cols
