"""Layer-1 Pallas kernel: batched complex mesh propagation + |.| detection.

The paper's compute hot-spot is the analog matrix-vector product: a batch
of (real) hidden activations streams through the N-channel mesh of 2x2
unit cells and the output magnitudes are detected (the |.| activation of
eq. 20 is physics, not software).

Hardware adaptation (see DESIGN.md #Hardware-Adaptation): the mesh is a
sequence of C columns, each a block-diagonal set of 2x2 complex rotations
on adjacent channel pairs. Instead of a GPU-style scatter per cell, each
column is encoded as three diagonal coefficient planes so one column step
is three vector multiplies and two static rolls -- dense, MXU/VPU-friendly
work with no gather:

    x' = A (.) x  +  B (.) shift_up(x)  +  C (.) shift_down(x)

where for a cell on channels (p, p+1):
    A[p] = t00, B[p] = t01  (partner below: shift_up brings x[p+1] to p)
    A[p+1] = t11, C[p+1] = t10
and untouched channels carry A = 1, B = C = 0.

Complex numbers are carried as separate re/im f32 planes (keeps the kernel
bf16-ready and avoids relying on complex support in Mosaic). The batch is
tiled through VMEM via BlockSpec; the (C, N) coefficient planes are tiny
and stay resident per program instance.

Pallas runs with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which both pytest and
the rust runtime execute. Structure (tiling, fusion) is what we optimize.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default batch tile: 128 rows x N channels x 2 planes x 4 B = 8 KiB at
# N = 8 -- far under VMEM; chosen so several buffers double-buffer cleanly.
DEFAULT_BLOCK_B = 128


def _mesh_abs_kernel(xr_ref, xi_ref, ar_ref, ai_ref, br_ref, bi_ref,
                     cr_ref, ci_ref, out_ref):
    """One batch tile: propagate through all C columns, emit magnitudes."""
    xr = xr_ref[...]
    xi = xi_ref[...]
    n_cols = ar_ref.shape[0]

    # The column count is static (mesh depth = 2N−3), so unroll the sweep:
    # XLA sees one straight-line fusion region instead of a `while` op with
    # per-iteration dynamic slices (§Perf: CPU wallclock parity with
    # lax.fori_loop — within run-to-run noise — but the unrolled HLO is the
    # TPU-friendly structure: no loop-carried buffer round-trips).
    for c in range(n_cols):
        ar = ar_ref[c, :]
        ai = ai_ref[c, :]
        br = br_ref[c, :]
        bi = bi_ref[c, :]
        cr = cr_ref[c, :]
        ci = ci_ref[c, :]
        # Partners: shift_up brings channel p+1 to p; shift_down brings
        # p-1 to p. Rolls are static-size, lowering to cheap slices.
        xur = jnp.roll(xr, -1, axis=1)
        xui = jnp.roll(xi, -1, axis=1)
        xdr = jnp.roll(xr, 1, axis=1)
        xdi = jnp.roll(xi, 1, axis=1)
        # Complex multiply-accumulate, re/im planes.
        yr = (ar * xr - ai * xi) + (br * xur - bi * xui) + (cr * xdr - ci * xdi)
        yi = (ar * xi + ai * xr) + (br * xui + bi * xur) + (cr * xdi + ci * xdr)
        xr, xi = yr, yi

    out_ref[...] = jnp.sqrt(xr * xr + xi * xi)


@functools.partial(jax.jit, static_argnames=("block_b",))
def mesh_abs(x, coeffs, block_b: int = DEFAULT_BLOCK_B):
    """Propagate a real batch through the mesh and detect magnitudes.

    Args:
      x: f32[B, N] real input batch (post-leaky-ReLU activations).
      coeffs: tuple of six f32[C, N] planes (ar, ai, br, bi, cr, ci).
      block_b: batch tile size (B must be a multiple, else it is padded).

    Returns:
      f32[B, N] output magnitudes |mesh @ x|.
    """
    ar, ai, br, bi, cr, ci = coeffs
    b, n = x.shape
    bb = min(block_b, b)
    pad = (-b) % bb
    xr = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    xi = jnp.zeros_like(xr)
    grid = (xr.shape[0] // bb,)

    batch_spec = pl.BlockSpec((bb, n), lambda i: (i, 0))
    coeff_spec = pl.BlockSpec(ar.shape, lambda i: (0, 0))
    out = pl.pallas_call(
        _mesh_abs_kernel,
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        grid=grid,
        in_specs=[batch_spec, batch_spec] + [coeff_spec] * 6,
        out_specs=batch_spec,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xr, xi, ar, ai, br, bi, cr, ci)
    return out[:b] if pad else out


def coeff_planes_from_columns(n: int, columns):
    """Build the six (C, N) coefficient planes from mesh columns.

    `columns` is a list of columns; each column is a list of
    (p, t) tuples where t is a complex 2x2 (nested lists/np-like) acting on
    channels (p, p+1). Channels not covered by a cell pass through.
    """
    import numpy as np

    c_cols = len(columns)
    ar = np.ones((c_cols, n), np.float32)
    ai = np.zeros((c_cols, n), np.float32)
    br = np.zeros((c_cols, n), np.float32)
    bi = np.zeros((c_cols, n), np.float32)
    cr = np.zeros((c_cols, n), np.float32)
    ci = np.zeros((c_cols, n), np.float32)
    for k, col in enumerate(columns):
        for p, t in col:
            t = np.asarray(t, np.complex64)
            ar[k, p], ai[k, p] = t[0, 0].real, t[0, 0].imag
            br[k, p], bi[k, p] = t[0, 1].real, t[0, 1].imag
            ar[k, p + 1], ai[k, p + 1] = t[1, 1].real, t[1, 1].imag
            cr[k, p + 1], ci[k, p + 1] = t[1, 0].real, t[1, 0].imag
    return (jnp.asarray(ar), jnp.asarray(ai), jnp.asarray(br),
            jnp.asarray(bi), jnp.asarray(cr), jnp.asarray(ci))


def reck_columns(n: int):
    """Reck-mesh column layout: list of columns of channel indices p.

    Mirrors rust/src/mesh/topology.rs (signal-flow order, greedy column
    packing); returns a list of lists of p values.
    """
    pairs = []
    for r in reversed(range(1, n)):
        for c in range(r):
            pairs.append(c)
    pairs.reverse()
    col_of_channel = [0] * n
    columns = []
    for p in pairs:
        col = max(col_of_channel[p], col_of_channel[p + 1])
        while len(columns) <= col:
            columns.append([])
        columns[col].append(p)
        col_of_channel[p] = col + 1
        col_of_channel[p + 1] = col + 1
    return columns


def _mesh_abs_dense_kernel(x_ref, mre_ref, mim_ref, out_ref):
    """Dense variant: out = |x @ (Mre + j*Mim)^T| for real x.

    Serving-path kernel (#Perf L1): the mesh matrix changes only when DSPSA
    re-biases the device (once per training step, never per request), so the
    coordinator precomposes M = prod(columns) and the kernel collapses the
    13-column sweep into two MXU-shaped matmuls + one elementwise
    magnitude. On CPU-PJRT this cut the b256 forward from ~65 ms to ~2 ms;
    on TPU it is also the right shape for N << 128 (the sweep underutilizes
    the systolic array).
    """
    x = x_ref[...]
    zre = jnp.dot(x, mre_ref[...].T)
    zim = jnp.dot(x, mim_ref[...].T)
    out_ref[...] = jnp.sqrt(zre * zre + zim * zim)


@functools.partial(jax.jit, static_argnames=("block_b",))
def mesh_abs_dense(x, m_re, m_im, block_b: int = DEFAULT_BLOCK_B):
    """|M @ x| with a precomposed complex mesh matrix (re/im planes).

    Args:
      x: f32[B, N] real input batch.
      m_re, m_im: f32[N, N] real/imaginary parts of the composed matrix.
      block_b: batch tile size.

    Returns:
      f32[B, N] detected output magnitudes.
    """
    b, n = x.shape
    bb = min(block_b, b)
    pad = (-b) % bb
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    grid = (xp.shape[0] // bb,)
    batch_spec = pl.BlockSpec((bb, n), lambda i: (i, 0))
    m_spec = pl.BlockSpec((n, n), lambda i: (0, 0))
    out = pl.pallas_call(
        _mesh_abs_dense_kernel,
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        grid=grid,
        in_specs=[batch_spec, m_spec, m_spec],
        out_specs=batch_spec,
        interpret=True,
    )(xp, m_re, m_im)
    return out[:b] if pad else out
