"""AOT lowering: JAX model -> HLO text artifacts for the rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Artifacts (per batch size B in BATCH_SIZES):
  rfnn_mnist_fwd[_bB].hlo.txt  -- full 4-layer forward -> probabilities
  mesh_abs[_bB].hlo.txt        -- analog stage only
  manifest.json                -- shapes and argument order for rust
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.mesh import reck_columns
from .model import mesh_abs_dense_only, mesh_abs_only, rfnn_forward, rfnn_forward_dense

# Mesh geometry (the paper's 8x8 processor: 28 cells, 13 columns).
N = 8
COLS = len(reck_columns(N))
# Exported batch sizes; the rust batcher pads to the nearest.
BATCH_SIZES = (1, 32, 256)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_all(out_dir: str) -> dict:
    coeff_specs = tuple(spec(COLS, N) for _ in range(6))
    manifest = {
        "n": N,
        "cols": COLS,
        "batch_sizes": list(BATCH_SIZES),
        "artifacts": {},
    }
    for b in BATCH_SIZES:
        # Serving path: dense precomposed-matrix kernel (§Perf L1 — the
        # column sweep costs ~67× more under interpret-mode CPU dispatch
        # and also underutilizes the MXU at N = 8).
        fwd = jax.jit(rfnn_forward_dense).lower(
            spec(b, 784), spec(N, 784), spec(N), spec(N, N), spec(N, N), spec(10, N), spec(10)
        )
        name = f"rfnn_mnist_fwd_b{b}.hlo.txt"
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(to_hlo_text(fwd))
        manifest["artifacts"][f"rfnn_mnist_fwd_b{b}"] = {
            "file": name,
            "args": ["x", "w1", "b1", "m_re", "m_im", "w2", "b2"],
            "arg_shapes": [[b, 784], [N, 784], [N], [N, N], [N, N], [10, N], [10]],
            "result_shape": [b, 10],
        }

        mesh = jax.jit(mesh_abs_dense_only).lower(spec(b, N), spec(N, N), spec(N, N))
        name = f"mesh_abs_b{b}.hlo.txt"
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(to_hlo_text(mesh))
        manifest["artifacts"][f"mesh_abs_b{b}"] = {
            "file": name,
            "args": ["x", "m_re", "m_im"],
            "arg_shapes": [[b, N], [N, N], [N, N]],
            "result_shape": [b, N],
        }

    # Ablation artifacts: the structural column-sweep variant (the
    # TPU-shaped schedule; see kernels/mesh.py) at the largest batch.
    b = BATCH_SIZES[-1]
    sweep = jax.jit(mesh_abs_only).lower(spec(b, N), coeff_specs)
    name = f"mesh_sweep_b{b}.hlo.txt"
    with open(os.path.join(out_dir, name), "w") as f:
        f.write(to_hlo_text(sweep))
    manifest["artifacts"][f"mesh_sweep_b{b}"] = {
        "file": name,
        "args": ["x", "ar", "ai", "br", "bi", "cr", "ci"],
        "arg_shapes": [[b, N]] + [[COLS, N]] * 6,
        "result_shape": [b, N],
    }
    fwd_sweep = jax.jit(rfnn_forward).lower(
        spec(b, 784), spec(N, 784), spec(N), coeff_specs, spec(10, N), spec(10)
    )
    name = f"rfnn_mnist_fwd_sweep_b{b}.hlo.txt"
    with open(os.path.join(out_dir, name), "w") as f:
        f.write(to_hlo_text(fwd_sweep))
    manifest["artifacts"][f"rfnn_mnist_fwd_sweep_b{b}"] = {
        "file": name,
        "args": ["x", "w1", "b1", "ar", "ai", "br", "bi", "cr", "ci", "w2", "b2"],
        "arg_shapes": [
            [b, 784], [N, 784], [N],
            [COLS, N], [COLS, N], [COLS, N], [COLS, N], [COLS, N], [COLS, N],
            [10, N], [10],
        ],
        "result_shape": [b, 10],
    }
    # The default-name alias the Makefile tracks.
    default = os.path.join(out_dir, "rfnn_mnist_fwd.hlo.txt")
    with open(os.path.join(out_dir, f"rfnn_mnist_fwd_b{BATCH_SIZES[-1]}.hlo.txt")) as src:
        with open(default, "w") as dst:
            dst.write(src.read())
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = lower_all(args.out_dir)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    total = sum(
        os.path.getsize(os.path.join(args.out_dir, a["file"]))
        for a in manifest["artifacts"].values()
    )
    print(f"wrote {len(manifest['artifacts'])} artifacts ({total} bytes) to {args.out_dir}")


if __name__ == "__main__":
    main()
