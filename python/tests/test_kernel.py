"""L1 correctness: Pallas mesh kernel vs two independent references.

Hypothesis sweeps shapes and mesh contents; assert_allclose against both
the complex column-sweep reference and the dense-matrix reference.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.mesh import (
    coeff_planes_from_columns,
    mesh_abs,
    reck_columns,
)
from compile.kernels.ref import (
    columns_to_matrix,
    mesh_abs_dense_ref,
    mesh_abs_ref,
    random_columns,
)

TOL = dict(rtol=1e-5, atol=1e-5)


def make_case(n, batch, seed, density=1.0):
    rng = np.random.default_rng(seed)
    cols = random_columns(n, rng, density)
    planes = coeff_planes_from_columns(n, cols)
    x = rng.normal(size=(batch, n)).astype(np.float32)
    return x, planes, cols


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([2, 4, 8]),
    batch=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_complex_reference(n, batch, seed):
    x, planes, _ = make_case(n, batch, seed)
    got = np.asarray(mesh_abs(x, planes))
    want = np.asarray(mesh_abs_ref(x, planes))
    assert_allclose(got, want, **TOL)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
    density=st.sampled_from([0.4, 1.0]),
)
def test_kernel_matches_dense_matrix(n, seed, density):
    x, planes, cols = make_case(n, 17, seed, density)
    got = np.asarray(mesh_abs(x, planes))
    want = mesh_abs_dense_ref(x, n, cols)
    assert_allclose(got, want, **TOL)


def test_unitary_mesh_conserves_power():
    x, planes, _ = make_case(8, 64, 123)
    y = np.asarray(mesh_abs(x, planes))
    assert_allclose(
        (y**2).sum(axis=1), (x**2).sum(axis=1), rtol=1e-4
    )  # all-unitary cells -> lossless


def test_identity_mesh_is_abs():
    n = 8
    cols = [[] for _ in reck_columns(n)]  # no cells: pure pass-through
    planes = coeff_planes_from_columns(n, cols)
    x = np.random.default_rng(5).normal(size=(9, n)).astype(np.float32)
    assert_allclose(np.asarray(mesh_abs(x, planes)), np.abs(x), **TOL)


@pytest.mark.parametrize("batch", [1, 127, 128, 129, 300])
def test_batch_padding_edges(batch):
    """Batch sizes around the VMEM tile boundary must all be exact."""
    x, planes, _ = make_case(8, batch, 77)
    got = np.asarray(mesh_abs(x, planes))
    want = np.asarray(mesh_abs_ref(x, planes))
    assert got.shape == (batch, 8)
    assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("block_b", [1, 2, 64, 512])
def test_block_size_invariance(block_b):
    """The tiling is a performance knob, never a numerics knob."""
    x, planes, _ = make_case(8, 65, 99)
    base = np.asarray(mesh_abs(x, planes))
    tiled = np.asarray(mesh_abs(x, planes, block_b=block_b))
    assert_allclose(tiled, base, rtol=1e-6, atol=1e-6)


def test_reck_columns_match_rust_topology():
    # N=8: 28 cells over 13 columns (2N-3); N=4: 6 cells over 5 columns.
    cols8 = reck_columns(8)
    assert sum(len(c) for c in cols8) == 28
    assert len(cols8) == 13
    cols4 = reck_columns(4)
    assert sum(len(c) for c in cols4) == 6
    assert len(cols4) == 5
    # No channel conflicts within a column.
    for col in cols8:
        used = set()
        for p in col:
            assert p not in used and p + 1 not in used
            used.update((p, p + 1))


def test_composed_matrix_is_unitary():
    rng = np.random.default_rng(11)
    cols = random_columns(8, rng)
    m = columns_to_matrix(8, cols)
    assert_allclose(m @ m.conj().T, np.eye(8), atol=1e-5)


# ---------------------------------------------------------------- dense --

from compile.kernels.mesh import mesh_abs_dense  # noqa: E402


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([2, 4, 8, 16]),
    batch=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_kernel_matches_sweep(n, batch, seed):
    """The serving-path dense kernel equals the column-sweep kernel."""
    x, planes, cols = make_case(n, batch, seed)
    m = columns_to_matrix(n, cols)
    got = np.asarray(
        mesh_abs_dense(x, m.real.astype(np.float32), m.imag.astype(np.float32))
    )
    want = np.asarray(mesh_abs(x, planes))
    assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_dense_kernel_identity():
    n = 8
    x = np.random.default_rng(4).normal(size=(12, n)).astype(np.float32)
    eye = np.eye(n, dtype=np.float32)
    zero = np.zeros((n, n), np.float32)
    got = np.asarray(mesh_abs_dense(x, eye, zero))
    assert_allclose(got, np.abs(x), **TOL)


@pytest.mark.parametrize("batch", [1, 127, 129, 257])
def test_dense_kernel_padding_edges(batch):
    x, planes, cols = make_case(8, batch, 31)
    m = columns_to_matrix(8, cols)
    got = np.asarray(
        mesh_abs_dense(x, m.real.astype(np.float32), m.imag.astype(np.float32))
    )
    assert got.shape == (batch, 8)
    want = mesh_abs_dense_ref(x, 8, cols)
    assert_allclose(got, want, rtol=2e-4, atol=2e-5)
