"""L2 correctness: the jax RFNN forward vs the numpy reference."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.mesh import coeff_planes_from_columns
from compile.kernels.ref import random_columns
from compile.model import reference_forward_np, rfnn_forward, rfnn_logits


def make_params(n, seed):
    rng = np.random.default_rng(seed)
    w1 = (rng.normal(size=(n, 784)) * 0.05).astype(np.float32)
    b1 = (rng.normal(size=(n,)) * 0.01).astype(np.float32)
    w2 = (rng.normal(size=(10, n)) * 0.3).astype(np.float32)
    b2 = np.zeros((10,), np.float32)
    cols = random_columns(n, rng)
    planes = coeff_planes_from_columns(n, cols)
    return w1, b1, planes, cols, w2, b2


@settings(max_examples=10, deadline=None)
@given(batch=st.integers(1, 24), seed=st.integers(0, 2**31 - 1))
def test_forward_matches_numpy_reference(batch, seed):
    n = 8
    w1, b1, planes, cols, w2, b2 = make_params(n, seed)
    x = np.random.default_rng(seed ^ 0xFF).normal(size=(batch, 784)).astype(np.float32)
    got = np.asarray(rfnn_forward(x, w1, b1, planes, w2, b2))
    want = reference_forward_np(x, w1, b1, n, cols, w2, b2)
    assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_probabilities_normalized():
    n = 8
    w1, b1, planes, _, w2, b2 = make_params(n, 3)
    x = np.random.default_rng(4).normal(size=(16, 784)).astype(np.float32)
    p = np.asarray(rfnn_forward(x, w1, b1, planes, w2, b2))
    assert p.shape == (16, 10)
    assert (p >= 0).all()
    assert_allclose(p.sum(axis=1), np.ones(16), rtol=1e-5)


def test_logits_consistent_with_probs():
    n = 8
    w1, b1, planes, _, w2, b2 = make_params(n, 5)
    x = np.random.default_rng(6).normal(size=(4, 784)).astype(np.float32)
    logits = np.asarray(rfnn_logits(x, w1, b1, planes, w2, b2))
    probs = np.asarray(rfnn_forward(x, w1, b1, planes, w2, b2))
    e = np.exp(logits - logits.max(axis=1, keepdims=True))
    assert_allclose(probs, e / e.sum(axis=1, keepdims=True), rtol=1e-5, atol=1e-6)


def test_mesh_stage_is_permutation_invariant_to_batch_order():
    n = 8
    w1, b1, planes, _, w2, b2 = make_params(n, 7)
    x = np.random.default_rng(8).normal(size=(6, 784)).astype(np.float32)
    p = np.asarray(rfnn_forward(x, w1, b1, planes, w2, b2))
    perm = [3, 1, 5, 0, 2, 4]
    p2 = np.asarray(rfnn_forward(x[perm], w1, b1, planes, w2, b2))
    assert_allclose(p2, p[perm], rtol=1e-5, atol=1e-6)
