"""AOT pipeline: lowering produces loadable HLO text with stable arity."""

import json
import os
import tempfile

from compile import aot


def test_lower_all_writes_artifacts_and_manifest():
    with tempfile.TemporaryDirectory() as d:
        manifest = aot.lower_all(d)
        # One fwd + one mesh artifact per batch size, plus the alias.
        files = set(os.listdir(d))
        for b in aot.BATCH_SIZES:
            assert f"rfnn_mnist_fwd_b{b}.hlo.txt" in files
            assert f"mesh_abs_b{b}.hlo.txt" in files
        assert "rfnn_mnist_fwd.hlo.txt" in files
        for key, art in manifest["artifacts"].items():
            path = os.path.join(d, art["file"])
            text = open(path).read()
            assert text.startswith("HloModule"), f"{key} is not HLO text"
            # The interchange gotcha: text, never serialized protos.
            assert "ENTRY" in text
            assert len(art["args"]) == len(art["arg_shapes"])


def test_manifest_round_trips_as_json():
    with tempfile.TemporaryDirectory() as d:
        manifest = aot.lower_all(d)
        s = json.dumps(manifest)
        assert json.loads(s) == manifest


def test_hlo_contains_no_custom_calls():
    """interpret=True must lower the Pallas kernel to plain HLO ops —
    a Mosaic custom-call would be unexecutable on the rust CPU client."""
    with tempfile.TemporaryDirectory() as d:
        aot.lower_all(d)
        text = open(os.path.join(d, "rfnn_mnist_fwd_b32.hlo.txt")).read()
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower()
